//! Virtualization layer (paper §4.4, Algorithms 3/7/8/9 — DESIGN.md S9).
//!
//! Maps an arbitrarily-sized operand onto a fixed `R×C` array of MCAs with
//! `r×c` cells each:
//!
//! * **Dimension matching** — `zeroPadding` semantics: every chunk is
//!   extracted zero-padded to the full cell geometry (ideal, non-ideal and
//!   large-scale cases fall out of the same path).
//! * **Chunk partitioning** — `blockPartition` + `generateMatChunksSet`:
//!   the operand is cut into an `⌈m/r⌉ × ⌈n/c⌉` grid of chunks; chunk
//!   `(i, j)` is assigned to MCA `(i mod R, j mod C)`.  When the problem
//!   exceeds the physical capacity, MCAs are *reassigned* — the
//!   reassignment count is the paper's Fig 5 normalization factor.
//! * `generateVecChunksSet`: the input vector splits along the same column
//!   grid.

use crate::matrices::MatrixSource;
use crate::util::ceil_div;

/// Physical geometry of the multi-MCA system.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SystemGeometry {
    /// MCA tile grid (the paper's R × C, R ≥ C).
    pub tile_rows: usize,
    pub tile_cols: usize,
    /// Cells per MCA (the paper's r × c; artifacts require square r = c).
    pub cell_size: usize,
}

impl SystemGeometry {
    pub fn new(tile_rows: usize, tile_cols: usize, cell_size: usize) -> SystemGeometry {
        assert!(tile_rows > 0 && tile_cols > 0 && cell_size > 0);
        SystemGeometry {
            tile_rows,
            tile_cols,
            cell_size,
        }
    }

    /// Total MCA count.
    pub fn mcas(&self) -> usize {
        self.tile_rows * self.tile_cols
    }

    /// Physical capacity (rows, cols) = (R·r, C·c).
    pub fn capacity(&self) -> (usize, usize) {
        (
            self.tile_rows * self.cell_size,
            self.tile_cols * self.cell_size,
        )
    }
}

/// One chunk of the partitioned operand and its physical assignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkSpec {
    /// Chunk grid coordinates.
    pub block_row: usize,
    pub block_col: usize,
    /// Operand coordinates of the chunk origin.
    pub row0: usize,
    pub col0: usize,
    /// Assigned MCA (tile coordinates and flat index).
    pub mca_row: usize,
    pub mca_col: usize,
    pub mca_index: usize,
}

/// The full partition/assignment plan for one operand.
#[derive(Clone, Debug)]
pub struct ChunkPlan {
    pub geometry: SystemGeometry,
    /// Operand dimensions.
    pub m: usize,
    pub n: usize,
    /// Chunk grid dimensions.
    pub grid_rows: usize,
    pub grid_cols: usize,
}

impl ChunkPlan {
    /// Plan the partition of an `m × n` operand.
    pub fn new(geometry: SystemGeometry, m: usize, n: usize) -> ChunkPlan {
        assert!(m > 0 && n > 0);
        let r = geometry.cell_size;
        ChunkPlan {
            geometry,
            m,
            n,
            grid_rows: ceil_div(m, r),
            grid_cols: ceil_div(n, r),
        }
    }

    pub fn total_chunks(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// The chunk at grid position (i, j).
    pub fn chunk(&self, i: usize, j: usize) -> ChunkSpec {
        debug_assert!(i < self.grid_rows && j < self.grid_cols);
        let (rr, cc) = (self.geometry.tile_rows, self.geometry.tile_cols);
        let (mi, mj) = (i % rr, j % cc);
        ChunkSpec {
            block_row: i,
            block_col: j,
            row0: i * self.geometry.cell_size,
            col0: j * self.geometry.cell_size,
            mca_row: mi,
            mca_col: mj,
            mca_index: mi * cc + mj,
        }
    }

    /// Iterate chunks in deterministic row-major order.
    pub fn chunks(&self) -> impl Iterator<Item = ChunkSpec> + '_ {
        (0..self.grid_rows)
            .flat_map(move |i| (0..self.grid_cols).map(move |j| self.chunk(i, j)))
    }

    /// Sparsity-aware chunk enumeration: iterate, in the same
    /// deterministic row-major order as [`chunks`](Self::chunks), exactly
    /// the chunks whose block intersects `source`'s nonzero pattern.
    ///
    /// Per block row, candidates come from the occupied chunk-column *set*
    /// reported by [`MatrixSource::occupied_col_chunks`] and are confirmed
    /// with [`MatrixSource::block_is_zero`].  A set (unlike the older
    /// span) carries interior gaps, so irregular patterns — an arrowhead's
    /// first-column spike plus its diagonal, block diagonals — skip the
    /// hole chunks between their extremes instead of probing each one.
    /// The walk is O(occupied blocks) for sources with exact structure
    /// (CSR) or a cheap column bound
    /// ([`BandedSource`](crate::matrices::BandedSource): the full
    /// `O(grid²)` scan at 65,536²/32² would visit 4M chunks, the band
    /// visits only the few per row that exist), and never worse than the
    /// full grid walk for dense sources.
    pub fn nonzero_chunks<'a>(
        &'a self,
        source: &'a dyn MatrixSource,
    ) -> impl Iterator<Item = ChunkSpec> + 'a {
        let tile = self.geometry.cell_size;
        (0..self.grid_rows)
            .flat_map(move |i| {
                source
                    .occupied_col_chunks(i * tile, tile, tile)
                    .into_iter()
                    .filter(move |&j| j < self.grid_cols)
                    .map(move |j| self.chunk(i, j))
            })
            .filter(move |spec| !source.block_is_zero(spec.row0, spec.col0, tile, tile))
    }

    /// Number of chunk assignments each MCA receives.
    pub fn assignments_per_mca(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.geometry.mcas()];
        for c in self.chunks() {
            counts[c.mca_index] += 1;
        }
        counts
    }

    /// The paper's Fig 5 normalization factor: the (max) number of times a
    /// single MCA must be reassigned to cover the operand.
    pub fn normalization_factor(&self) -> usize {
        self.assignments_per_mca().into_iter().max().unwrap_or(1).max(1)
    }

    /// `true` when the operand fits the physical capacity without
    /// reassignment (the paper's "ideal"/"non-ideal" cases).
    pub fn fits_physically(&self) -> bool {
        self.normalization_factor() == 1
    }

    /// Per-dimension reassignment count — the paper's Fig 5 normalization
    /// constant ("each MCA is assigned approximately two (2) times" for
    /// Dubcova1 on an 8×1024 system counts the row direction).
    pub fn row_reassignments(&self) -> usize {
        ceil_div(self.grid_rows, self.geometry.tile_rows).max(1)
    }

    /// Padded operand dimensions after `zeroPadding` (Alg. 7).
    pub fn padded_dims(&self) -> (usize, usize) {
        (
            self.grid_rows * self.geometry.cell_size,
            self.grid_cols * self.geometry.cell_size,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_case_one_chunk_per_mca() {
        // 8x8 tiles of 1024² cells, operand exactly 8192².
        let g = SystemGeometry::new(8, 8, 1024);
        let plan = ChunkPlan::new(g, 8192, 8192);
        assert_eq!(plan.total_chunks(), 64);
        assert!(plan.fits_physically());
        assert_eq!(plan.normalization_factor(), 1);
        let counts = plan.assignments_per_mca();
        assert!(counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn non_ideal_case_pads() {
        // 66² on one 128² MCA: single chunk, zero-padded.
        let g = SystemGeometry::new(1, 1, 128);
        let plan = ChunkPlan::new(g, 66, 66);
        assert_eq!(plan.total_chunks(), 1);
        assert_eq!(plan.padded_dims(), (128, 128));
        assert!(plan.fits_physically());
    }

    #[test]
    fn large_scale_reassigns() {
        // The paper's example: Dubcova1 (16129²) on 8×8×1024² ->
        // each MCA assigned ~2 times -> normalization factor 2.
        let g = SystemGeometry::new(8, 8, 1024);
        let plan = ChunkPlan::new(g, 16129, 16129);
        assert_eq!(plan.grid_rows, 16);
        assert_eq!(plan.normalization_factor(), 4); // 16x16 grid on 8x8 tiles
                                                    // NOTE: the paper counts row-direction reassignment (~2); both are
                                                    // exposed — benches use the row factor, see `row_reassignments`.
    }

    #[test]
    fn weak_scaling_reassignment_counts() {
        // add32 (4960²), 8×8 tiles, cell 32² -> 155² chunks over 64 MCAs.
        let g = SystemGeometry::new(8, 8, 32);
        let plan = ChunkPlan::new(g, 4960, 4960);
        assert_eq!(plan.grid_rows, 155);
        assert!(!plan.fits_physically());
        // With cell 1024 the same operand fits physically (5x5 grid <= 8x8).
        let g = SystemGeometry::new(8, 8, 1024);
        let plan = ChunkPlan::new(g, 4960, 4960);
        assert!(plan.fits_physically());
    }

    #[test]
    fn non_square_operand_plan() {
        // 100x40 on 2x2 tiles of 32²: 4x2 chunk grid, rows reassign.
        let g = SystemGeometry::new(2, 2, 32);
        let plan = ChunkPlan::new(g, 100, 40);
        assert_eq!((plan.grid_rows, plan.grid_cols), (4, 2));
        assert_eq!(plan.total_chunks(), 8);
        assert_eq!(plan.padded_dims(), (128, 64));
        assert_eq!(plan.row_reassignments(), 2);
        assert!(!plan.fits_physically());
        let last = plan.chunk(3, 1);
        assert_eq!((last.row0, last.col0), (96, 32));
        assert_eq!((last.mca_row, last.mca_col), (1, 1));
        assert_eq!(last.mca_index, 3);
    }

    #[test]
    fn operand_smaller_than_cell() {
        // 20x7 on 4x4 tiles of 128²: one zero-padded chunk on MCA 0.
        let g = SystemGeometry::new(4, 4, 128);
        let plan = ChunkPlan::new(g, 20, 7);
        assert_eq!(plan.total_chunks(), 1);
        assert_eq!(plan.padded_dims(), (128, 128));
        assert!(plan.fits_physically());
        assert_eq!(plan.normalization_factor(), 1);
        assert_eq!(plan.row_reassignments(), 1);
        let c = plan.chunk(0, 0);
        assert_eq!((c.row0, c.col0, c.mca_index), (0, 0, 0));
        let counts = plan.assignments_per_mca();
        assert_eq!(counts.iter().sum::<usize>(), 1);
        assert_eq!(counts[0], 1);
    }

    #[test]
    fn short_wide_operand_m_below_cell() {
        // m < cell_size but n spans several columns of chunks.
        let g = SystemGeometry::new(2, 2, 32);
        let plan = ChunkPlan::new(g, 20, 100);
        assert_eq!((plan.grid_rows, plan.grid_cols), (1, 4));
        assert_eq!(plan.total_chunks(), 4);
        assert_eq!(plan.padded_dims(), (32, 128));
        let c = plan.chunk(0, 3);
        assert_eq!((c.row0, c.col0), (0, 96));
        assert_eq!((c.mca_row, c.mca_col), (0, 1));
        assert_eq!(c.mca_index, 1);
        // Only the first tile row of MCAs is ever used.
        let counts = plan.assignments_per_mca();
        assert_eq!(counts, vec![2, 2, 0, 0]);
    }

    #[test]
    fn chunk_assignment_round_robin() {
        let g = SystemGeometry::new(2, 2, 32);
        let plan = ChunkPlan::new(g, 128, 128); // 4x4 grid on 2x2 tiles
        let c = plan.chunk(3, 2);
        assert_eq!((c.mca_row, c.mca_col), (1, 0));
        assert_eq!(c.mca_index, 2);
        assert_eq!((c.row0, c.col0), (96, 64));
        let counts = plan.assignments_per_mca();
        assert_eq!(counts, vec![4, 4, 4, 4]);
    }

    #[test]
    fn chunks_iterate_in_row_major_order() {
        let g = SystemGeometry::new(2, 2, 16);
        let plan = ChunkPlan::new(g, 40, 40);
        let order: Vec<(usize, usize)> = plan.chunks().map(|c| (c.block_row, c.block_col)).collect();
        assert_eq!(
            order,
            vec![
                (0, 0),
                (0, 1),
                (0, 2),
                (1, 0),
                (1, 1),
                (1, 2),
                (2, 0),
                (2, 1),
                (2, 2)
            ]
        );
    }

    #[test]
    fn capacity_math() {
        let g = SystemGeometry::new(8, 8, 1024);
        assert_eq!(g.capacity(), (8192, 8192));
        assert_eq!(g.mcas(), 64);
    }

    #[test]
    fn nonzero_chunks_matches_filtered_full_walk() {
        use crate::matrices::BandedSource;
        let src = BandedSource::new(1000, 8, 1.0, 10.0, 0.2, 5);
        let g = SystemGeometry::new(2, 2, 32);
        let plan = ChunkPlan::new(g, 1000, 1000);
        let tile = g.cell_size;
        let full: Vec<(usize, usize)> = plan
            .chunks()
            .filter(|c| !src.block_is_zero(c.row0, c.col0, tile, tile))
            .map(|c| (c.block_row, c.block_col))
            .collect();
        let streamed: Vec<(usize, usize)> = plan
            .nonzero_chunks(&src)
            .map(|c| (c.block_row, c.block_col))
            .collect();
        // Same set, same deterministic row-major order.
        assert_eq!(full, streamed);
        // And far fewer than the full grid (sparsity pays off).
        assert!(streamed.len() * 5 < plan.total_chunks(), "{}", streamed.len());
    }

    #[test]
    fn nonzero_chunks_covers_dense_sources() {
        use crate::linalg::Matrix;
        use crate::matrices::DenseSource;
        let src = DenseSource::new(Matrix::standard_normal(48, 80, 13));
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), 48, 80);
        // A dense source has no column bound: every chunk is a candidate.
        let all: Vec<(usize, usize)> = plan
            .nonzero_chunks(&src)
            .map(|c| (c.block_row, c.block_col))
            .collect();
        let full: Vec<(usize, usize)> = plan
            .chunks()
            .map(|c| (c.block_row, c.block_col))
            .collect();
        assert_eq!(all, full);
    }

    #[test]
    fn nonzero_chunks_is_band_bounded() {
        use crate::matrices::BandedSource;
        // Band half-width 48 ≤ cell 1024: at most 3 candidate chunks per
        // block row, so the enumeration is O(grid_rows), not O(grid²).
        let n = 65_536;
        let src = BandedSource::new(n, 48, 4.0, 100.0, 0.2, 7);
        let plan = ChunkPlan::new(SystemGeometry::new(8, 8, 1024), n, n);
        let count = plan.nonzero_chunks(&src).count();
        assert!(count >= plan.grid_rows, "{count}");
        assert!(count <= 3 * plan.grid_rows, "{count}");
        assert_eq!(plan.total_chunks(), 64 * 64);
    }

    #[test]
    fn nonzero_chunks_skips_interior_hole_chunks() {
        use crate::matrices::{CsrSource, MatrixSource};
        use std::sync::atomic::{AtomicUsize, Ordering};

        /// Wrapper counting how many candidate chunks reach the
        /// `block_is_zero` confirmation probe.
        struct Probed {
            inner: CsrSource,
            probes: AtomicUsize,
        }
        impl MatrixSource for Probed {
            fn nrows(&self) -> usize {
                self.inner.nrows()
            }
            fn ncols(&self) -> usize {
                self.inner.ncols()
            }
            fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> crate::linalg::Matrix {
                self.inner.block(r0, c0, h, w)
            }
            fn matvec(&self, x: &crate::linalg::Vector) -> crate::linalg::Vector {
                self.inner.matvec(x)
            }
            fn block_is_zero(&self, r0: usize, c0: usize, h: usize, w: usize) -> bool {
                self.probes.fetch_add(1, Ordering::Relaxed);
                self.inner.block_is_zero(r0, c0, h, w)
            }
            fn occupied_cols(&self, r0: usize, rows: usize) -> (usize, usize) {
                self.inner.occupied_cols(r0, rows)
            }
            fn occupied_col_chunks(&self, r0: usize, rows: usize, tile: usize) -> Vec<usize> {
                self.inner.occupied_col_chunks(r0, rows, tile)
            }
            fn max_abs(&self) -> f64 {
                self.inner.max_abs()
            }
        }

        // Arrowhead: full first row/column + diagonal.  Away from the top,
        // each block row occupies exactly chunk column 0 and its diagonal
        // chunk — the span between them is all holes.
        let n = 512;
        let mut trip: Vec<(usize, usize, f64)> = (0..n).map(|j| (0, j, 1.0)).collect();
        trip.extend((1..n).map(|i| (i, 0, 1.0)));
        trip.extend((1..n).map(|i| (i, i, 4.0)));
        let src = Probed {
            inner: CsrSource::from_triplets(n, n, &trip).unwrap(),
            probes: AtomicUsize::new(0),
        };
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), n, n);
        let planned = plan.nonzero_chunks(&src).count();
        let probes = src.probes.load(Ordering::Relaxed);
        // Row chunk 0 spans all 16 columns; each of the other 15 row
        // chunks occupies {0, diag} only.
        assert_eq!(planned, plan.grid_cols + (plan.grid_rows - 1) * 2);
        // Exact occupied sets: every probe confirms a real chunk, no hole
        // chunk between column 0 and the diagonal is ever probed (the old
        // span walk probed the full triangle, ~8x more).
        assert_eq!(probes, planned);
    }
}
