//! Restarted GMRES(m) for general (nonsymmetric) operands.
//!
//! Each cycle runs up to `m` Arnoldi steps through the
//! [`KrylovWorkspace`] (modified Gram–Schmidt + Givens QR, all f64
//! host-side), then folds the least-squares update into `x` and restarts
//! from a freshly measured residual.  The restart residual costs one
//! extra MVM but keeps the method honest on a noisy operator: the
//! recurrence estimate inside a cycle cannot silently drift away from
//! the operator's actual output.

use super::{IterationOutcome, MvmOperator};
use crate::linalg::krylov::KrylovWorkspace;
use crate::linalg::Vector;

/// Solve `Ax = b` from `x₀ = 0` with GMRES(`restart`) within `max_iters`
/// total MVMs (Arnoldi steps plus restart residual measurements).
pub fn solve(
    op: &dyn MvmOperator,
    b: &Vector,
    tol: f64,
    max_iters: usize,
    restart: usize,
) -> Result<IterationOutcome, String> {
    let n = b.len();
    let bnorm = b.norm_l2();
    let mut x = Vector::zeros(n);
    let mut history = Vec::new();
    if bnorm == 0.0 {
        history.push(0.0);
        return Ok(IterationOutcome {
            x,
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history,
        });
    }
    let m = restart.clamp(1, n.max(1));
    let mut ws = KrylovWorkspace::new(m);
    let mut iterations = 0;
    let mut converged = false;
    let mut rel;
    loop {
        // Measured residual at the current iterate (free on cycle 0).
        let r = if iterations == 0 {
            b.clone()
        } else {
            let ax = op.apply(&x)?;
            iterations += 1;
            b.sub(&ax)
        };
        rel = r.norm_l2() / bnorm;
        history.push(rel);
        if rel <= tol {
            converged = true;
            break;
        }
        if iterations >= max_iters {
            break;
        }
        ws.reset(&r);
        let mut estimate = rel;
        while ws.can_expand() && iterations < max_iters {
            let w = op.apply(ws.last())?;
            iterations += 1;
            estimate = ws.expand(w) / bnorm;
            history.push(estimate);
            if estimate <= tol {
                break;
            }
        }
        // The pre-cycle budget guard plus a nonzero residual guarantee at
        // least one Arnoldi step ran (`solution` asserts it).
        x.add_assign(&ws.solution());
        // Budget exhausted: stop on the in-cycle estimate without a
        // verification MVM (converged stays false — the estimate alone
        // never ends the solve).  Otherwise loop back, where the restart
        // re-measures the true residual.
        if iterations >= max_iters {
            rel = estimate;
            break;
        }
    }
    Ok(IterationOutcome {
        x,
        iterations,
        converged,
        rel_residual: rel,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::ExactOperator;
    use crate::linalg::Matrix;
    use crate::matrices::generators;
    use crate::matrices::DenseSource;

    fn nonsym_source(n: usize, kappa: f64, seed: u64) -> DenseSource {
        DenseSource::new(generators::dense_nonsymmetric_with_condition(
            n, 4.0, kappa, 0.25, 6, seed,
        ))
    }

    #[test]
    fn converges_on_nonsymmetric_operand() {
        let n = 32;
        let src = nonsym_source(n, 50.0, 3);
        let x_star = Vector::standard_normal(n, 4);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-10, 300, n).unwrap();
        assert!(out.converged, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-7, "{err}");
    }

    #[test]
    fn restarted_cycles_still_converge() {
        let n = 32;
        let src = nonsym_source(n, 20.0, 5);
        let x_star = Vector::standard_normal(n, 6);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(&src);
        // Short restarts force several cycles.
        let out = solve(&op, &b, 1e-8, 500, 8).unwrap();
        assert!(out.converged, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-5, "{err}");
    }

    #[test]
    fn identity_converges_in_one_step() {
        let src = DenseSource::new(Matrix::identity(12));
        let b = Vector::standard_normal(12, 7);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-12, 20, 12).unwrap();
        assert!(out.converged);
        // One Arnoldi step + one restart residual check.
        assert!(out.iterations <= 2, "{}", out.iterations);
        let err = out.x.sub(&b).norm_l2() / b.norm_l2();
        assert!(err < 1e-12, "{err}");
    }

    #[test]
    fn budget_exhaustion_reports_not_converged() {
        let n = 24;
        let src = nonsym_source(n, 1e4, 9);
        let b = Vector::standard_normal(n, 10);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-14, 4, 2).unwrap();
        assert!(!out.converged);
        assert!(out.iterations <= 4);
        assert!(out.rel_residual > 0.0);
    }

    #[test]
    fn zero_rhs_is_trivial() {
        let src = DenseSource::new(Matrix::identity(6));
        let op = ExactOperator::new(&src);
        let out = solve(&op, &Vector::zeros(6), 1e-10, 10, 6).unwrap();
        assert!(out.converged);
        assert_eq!(out.iterations, 0);
    }
}
