//! Iterative `Ax = b` solvers on resident crossbar sessions.
//!
//! MELISO+ is an *in-memory linear solver*, and iterative methods are
//! where RRAM crossbars earn that name: every Krylov/stationary iteration
//! is one matrix–vector product, and a resident
//! [`Session`](crate::server::Session) serves those
//! products against an operand that was write–verified **once** — the
//! expensive conductance write amortizes across the entire solve (and
//! across repeated solves), while each iteration costs only an input
//! encode and crossbar reads.
//!
//! * [`stationary`] — Jacobi and damped Richardson sweeps.
//! * [`cg`] — conjugate gradient for SPD operands.
//! * [`gmres`] — restarted GMRES(m) for general operands (built on
//!   [`crate::linalg::krylov`]).
//!
//! All methods run against the backend-agnostic [`MvmOperator`] trait, so
//! the same code solves through an exact f64 reference
//! ([`ExactOperator`], used to validate the math to machine precision) or
//! through the analog serving path.  Scalar bookkeeping (dots, norms,
//! recurrences) is always f64 host-side.
//!
//! **Iterative refinement.**  Analog MVMs carry device noise, so a plain
//! Krylov solve stagnates at the device's error floor.  [`solve_system`]
//! wraps the inner method in classic iterative refinement: the residual
//! `r = b − Ax` is computed *exactly* in f64 on the host, the (noisy)
//! crossbar solves only the correction system `Ad = r`, and corrections
//! that fail to shrink the true residual are rejected.  As long as each
//! inner solve has relative error below one — orders of magnitude looser
//! than the device floor — the true residual contracts geometrically, so
//! low-precision devices still reach tight tolerances end-to-end (the
//! paper's "lower-precision devices outperform high-precision
//! alternatives" claim, measured on the full solve).
//!
//! Front door for users: [`crate::solver::Meliso::solve_system`]:
//!
//! ```
//! use meliso::prelude::*;
//!
//! let a = meliso::matrices::registry::build("spd64").unwrap();
//! let b = a.matvec(&Vector::standard_normal(a.ncols(), 3));
//! let opts = SolveOptions::default()
//!     .with_device(Material::EpiRam)
//!     .with_wv_iters(4)
//!     .with_backend(BackendKind::Native);
//! let report = Meliso::new(SystemConfig::single_mca(64), opts).unwrap()
//!     .solve_system(a, &b, &IterOptions::default().with_method(Method::Cg))
//!     .unwrap();
//! assert!(report.converged && report.programming_passes == 1);
//! ```

pub mod cg;
pub mod gmres;
pub mod stationary;

use crate::linalg::Vector;
use crate::matrices::MatrixSource;
use crate::plane::{OperandId, PlaneError, PlaneHandle};
pub use crate::server::MvmOperator;
use std::sync::atomic::{AtomicU64, Ordering};

/// Which iterative method drives the solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Jacobi sweeps `x ← x + D⁻¹(b − Ax)` (diagonally dominant operands).
    Jacobi,
    /// Damped Richardson `x ← x + ω(b − Ax)`.
    Richardson,
    /// Conjugate gradient (SPD operands).
    Cg,
    /// Restarted GMRES(m) (general operands).
    Gmres,
}

impl Method {
    pub const ALL: [Method; 4] = [
        Method::Jacobi,
        Method::Richardson,
        Method::Cg,
        Method::Gmres,
    ];

    /// Parse a CLI/config name.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "jacobi" => Some(Method::Jacobi),
            "richardson" => Some(Method::Richardson),
            "cg" | "conjugate-gradient" => Some(Method::Cg),
            "gmres" => Some(Method::Gmres),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Jacobi => "jacobi",
            Method::Richardson => "richardson",
            Method::Cg => "cg",
            Method::Gmres => "gmres",
        }
    }
}

impl std::fmt::Display for Method {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Options for one iterative solve.
#[derive(Clone, Debug)]
pub struct IterOptions {
    pub method: Method,
    /// Target relative residual `‖b − Ax‖₂ / ‖b‖₂`.
    pub tol: f64,
    /// MVM budget per inner solve.
    pub max_iters: usize,
    /// GMRES restart length m.
    pub restart: usize,
    /// Richardson relaxation ω.
    pub omega: f64,
    /// Outer iterative-refinement steps (0 = single inner solve, no
    /// exact-residual correction loop).
    pub max_refinements: usize,
    /// Inner-solve tolerance during refinement (the device floor makes
    /// anything much tighter unreachable anyway).
    pub inner_tol: f64,
}

impl Default for IterOptions {
    fn default() -> Self {
        IterOptions {
            method: Method::Cg,
            tol: 1e-6,
            max_iters: 200,
            restart: 32,
            omega: 1.0,
            max_refinements: 40,
            inner_tol: 1e-2,
        }
    }
}

impl IterOptions {
    pub fn with_method(mut self, m: Method) -> Self {
        self.method = m;
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    pub fn with_restart(mut self, m: usize) -> Self {
        self.restart = m;
        self
    }

    pub fn with_omega(mut self, w: f64) -> Self {
        self.omega = w;
        self
    }

    pub fn with_refinements(mut self, n: usize) -> Self {
        self.max_refinements = n;
        self
    }

    pub fn with_inner_tol(mut self, tol: f64) -> Self {
        self.inner_tol = tol;
        self
    }
}

/// Result of one inner method run (recurrence-based bookkeeping).
#[derive(Clone, Debug)]
pub struct IterationOutcome {
    pub x: Vector,
    /// MVMs consumed.
    pub iterations: usize,
    pub converged: bool,
    /// Final relative residual estimate (recurrence-based — the true
    /// residual of a noisy operator can sit above it).
    pub rel_residual: f64,
    /// Per-iteration relative residual estimates.
    pub history: Vec<f64>,
}

/// Outcome of a full [`solve_system`] run.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub x: Vector,
    pub converged: bool,
    /// Final relative residual — exact f64 when an exact source was
    /// supplied, the inner estimate otherwise.
    pub rel_residual: f64,
    /// Total MVM-bearing inner iterations.
    pub iterations: usize,
    /// Outer refinement corrections applied.
    pub refinements: usize,
    /// Residual trajectory: inner estimates, plus the exact outer
    /// residuals when refinement runs.
    pub history: Vec<f64>,
    /// MVMs served by the operator over this solve.
    pub mvms: u64,
}

/// Exact f64 reference operator over a [`MatrixSource`] — validates the
/// solver math to machine precision and serves as the digital baseline in
/// comparisons.
pub struct ExactOperator<'a> {
    source: &'a dyn MatrixSource,
    count: AtomicU64,
}

impl ExactOperator<'_> {
    pub fn new(source: &dyn MatrixSource) -> ExactOperator<'_> {
        ExactOperator {
            source,
            count: AtomicU64::new(0),
        }
    }
}

impl MvmOperator for ExactOperator<'_> {
    fn nrows(&self) -> usize {
        self.source.nrows()
    }

    fn ncols(&self) -> usize {
        self.source.ncols()
    }

    fn apply(&self, x: &Vector) -> Result<Vector, String> {
        self.count.fetch_add(1, Ordering::Relaxed);
        Ok(self.source.matvec(x))
    }

    fn mvm_count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact references never touch the crossbar.
    fn programming_passes(&self) -> u64 {
        0
    }
}

/// [`MvmOperator`] over one residency of a (shared, multi-tenant)
/// execution plane: several systems can be solved *concurrently* against
/// operands sharing one shard pool (each `apply` admits its batch through
/// the clone-able [`PlaneHandle`] — no plane-wide lock), without the
/// serving-statistics machinery of a full [`crate::server::Session`].
///
/// [`program`](PlaneOperator::program) pays the single write–verify pass;
/// every [`apply`](MvmOperator::apply) afterwards is reads only, drawing
/// from the same counter-based noise streams as a dedicated plane — so a
/// solve through a `PlaneOperator` is bit-identical to one through a
/// dedicated session with the same seed.  Dropping the operator evicts
/// its residency.
pub struct PlaneOperator {
    plane: PlaneHandle,
    id: OperandId,
    m: usize,
    n: usize,
    mvms: AtomicU64,
}

impl PlaneOperator {
    /// Program `source` resident on `plane` and wrap the residency as an
    /// MVM operator.
    pub fn program(
        plane: &PlaneHandle,
        source: &dyn MatrixSource,
    ) -> Result<PlaneOperator, PlaneError> {
        let (id, report) = plane.program(source)?;
        Ok(PlaneOperator {
            plane: plane.clone(),
            id,
            m: report.m,
            n: report.n,
            mvms: AtomicU64::new(0),
        })
    }

    /// The residency handle on the underlying plane.
    pub fn id(&self) -> OperandId {
        self.id
    }
}

impl Drop for PlaneOperator {
    fn drop(&mut self) {
        let _ = self.plane.evict(self.id);
    }
}

impl MvmOperator for PlaneOperator {
    fn nrows(&self) -> usize {
        self.m
    }

    fn ncols(&self) -> usize {
        self.n
    }

    fn apply(&self, x: &Vector) -> Result<Vector, String> {
        let mut batch = self
            .plane
            .execute_batch(self.id, std::slice::from_ref(x))
            .map_err(String::from)?;
        self.mvms.fetch_add(1, Ordering::Relaxed);
        batch
            .solves
            .pop()
            .map(|s| s.y)
            .ok_or_else(|| "empty batch result".to_string())
    }

    fn mvm_count(&self) -> u64 {
        self.mvms.load(Ordering::Relaxed)
    }

    /// One write–verify pass at [`program`](PlaneOperator::program) time.
    fn programming_passes(&self) -> u64 {
        1
    }
}

const JACOBI_NEEDS_DIAG: &str = "jacobi needs the operand diagonal — supply the exact source";

/// Extract the diagonal of a (square) operand — Jacobi's preconditioner,
/// read exactly on the host.
pub fn diagonal(source: &dyn MatrixSource) -> Vector {
    let n = source.nrows().min(source.ncols());
    let mut d = Vector::zeros(n);
    for i in 0..n {
        d.set(i, source.block(i, i, 1, 1).get(0, 0));
    }
    d
}

/// Dispatch one inner solve of `A x = b` from `x₀ = 0`.
fn run_inner(
    op: &dyn MvmOperator,
    diag: Option<&Vector>,
    b: &Vector,
    tol: f64,
    opts: &IterOptions,
) -> Result<IterationOutcome, String> {
    match opts.method {
        Method::Jacobi => {
            // Unreachable via `solve_system` (which resolves the diagonal
            // up front), kept as defense for direct callers.
            let d = diag.ok_or_else(|| JACOBI_NEEDS_DIAG.to_string())?;
            stationary::jacobi(op, d, b, tol, opts.max_iters)
        }
        Method::Richardson => stationary::richardson(op, opts.omega, b, tol, opts.max_iters),
        Method::Cg => cg::solve(op, b, tol, opts.max_iters),
        Method::Gmres => gmres::solve(op, b, tol, opts.max_iters, opts.restart),
    }
}

/// Mirror one finished iterative solve into the global metrics registry:
/// inner-iteration counter and final-residual gauge, labelled by method.
fn publish_outcome(method: Method, out: &SolveOutcome) {
    if !crate::obs::metrics_on() {
        return;
    }
    let labels: &[(&str, &str)] = &[("method", method.name())];
    let g = crate::obs::global();
    g.counter(
        crate::obs::names::ITER_ITERATIONS,
        "Iterative-solver inner iterations",
        labels,
    )
    .add(out.iterations as f64);
    g.gauge(
        crate::obs::names::ITER_RESIDUAL,
        "Iterative-solver final relative residual",
        labels,
    )
    .set(out.rel_residual);
}

/// Solve `Ax = b` with the configured method, optionally wrapped in
/// exact-residual iterative refinement (see the module docs).
///
/// * `op` serves the MVMs (resident session or exact reference);
/// * `exact`, when given, computes true f64 residuals on the host and
///   enables the refinement loop (`opts.max_refinements > 0`);
/// * refinement is **monotone**: a correction that fails to shrink the
///   true residual is rolled back, and three consecutive stalls stop the
///   loop — a noisy inner solver can never drive the solution away.
pub fn solve_system(
    op: &dyn MvmOperator,
    exact: Option<&dyn MatrixSource>,
    b: &Vector,
    opts: &IterOptions,
) -> Result<SolveOutcome, String> {
    let n = op.ncols();
    if op.nrows() != n {
        return Err(format!(
            "iterative methods need a square operand, got {}x{}",
            op.nrows(),
            n
        ));
    }
    if b.len() != n {
        return Err(format!("b has length {}, A is {n}x{n}", b.len()));
    }
    if let Some(src) = exact {
        if src.nrows() != op.nrows() || src.ncols() != op.ncols() {
            return Err(format!(
                "exact source is {}x{}, operator is {}x{n}",
                src.nrows(),
                src.ncols(),
                op.nrows()
            ));
        }
    }
    let bnorm = b.norm_l2();
    if bnorm == 0.0 {
        return Ok(SolveOutcome {
            x: Vector::zeros(n),
            converged: true,
            rel_residual: 0.0,
            iterations: 0,
            refinements: 0,
            history: vec![0.0],
            mvms: 0,
        });
    }
    let diag = if opts.method == Method::Jacobi {
        let src = exact.ok_or_else(|| JACOBI_NEEDS_DIAG.to_string())?;
        Some(diagonal(src))
    } else {
        None
    };
    let mvms0 = op.mvm_count();

    let src = match exact {
        Some(src) if opts.max_refinements > 0 => src,
        _ => {
            // Single inner solve; measure the true residual when possible.
            let out = run_inner(op, diag.as_ref(), b, opts.tol, opts)?;
            let mut history = out.history;
            let (rel, converged) = match exact {
                Some(src) => {
                    let r = b.sub(&src.matvec(&out.x));
                    let rel = r.norm_l2() / bnorm;
                    history.push(rel);
                    (rel, rel <= opts.tol)
                }
                None => (out.rel_residual, out.converged),
            };
            let outcome = SolveOutcome {
                x: out.x,
                converged,
                rel_residual: rel,
                iterations: out.iterations,
                refinements: 0,
                history,
                mvms: op.mvm_count() - mvms0,
            };
            publish_outcome(opts.method, &outcome);
            return Ok(outcome);
        }
    };

    // Refinement loop: exact residual on the host, noisy correction solve
    // on the device, monotone accept.
    let mut x = Vector::zeros(n);
    let mut history = Vec::new();
    let mut iterations = 0usize;
    let mut refinements = 0usize;
    let mut best_rel = f64::INFINITY;
    let mut best_x = x.clone();
    let mut best_r = b.clone();
    let mut stalls = 0usize;
    let mut converged = false;
    loop {
        let r = b.sub(&src.matvec(&x));
        let rel = r.norm_l2() / bnorm;
        history.push(rel);
        if rel < best_rel {
            best_rel = rel;
            best_x = x.clone();
            best_r = r;
            stalls = 0;
        } else {
            // Roll the stalled correction back before trying again (a
            // noisy inner solver draws fresh noise on the retry).
            x = best_x.clone();
            stalls += 1;
        }
        if best_rel <= opts.tol {
            converged = true;
            break;
        }
        if refinements >= opts.max_refinements || stalls >= 3 {
            break;
        }
        let inner = run_inner(op, diag.as_ref(), &best_r, opts.inner_tol, opts)?;
        iterations += inner.iterations;
        // Inner estimates are residuals of the *correction* system
        // `Ad = r`; rescale them into the outer `‖b − Ax‖/‖b‖` frame so
        // the recorded trajectory reads as one curve.
        let frame = best_r.norm_l2() / bnorm;
        history.extend(inner.history.iter().skip(1).map(|e| e * frame));
        x.add_assign(&inner.x);
        refinements += 1;
    }
    let outcome = SolveOutcome {
        x: best_x,
        converged,
        rel_residual: best_rel,
        iterations,
        refinements,
        history,
        mvms: op.mvm_count() - mvms0,
    };
    publish_outcome(opts.method, &outcome);
    Ok(outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrices::generators;
    use crate::matrices::DenseSource;

    fn spd_source(n: usize, kappa: f64, seed: u64) -> DenseSource {
        DenseSource::new(generators::dense_spd_with_condition(n, 3.0, kappa, 6, seed))
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("CG"), Some(Method::Cg));
        assert_eq!(Method::parse("sor"), None);
        assert_eq!(Method::Gmres.to_string(), "gmres");
    }

    #[test]
    fn diagonal_reads_exactly() {
        let src = spd_source(12, 10.0, 5);
        let d = diagonal(&src);
        for i in 0..12 {
            assert_eq!(d.get(i), src.matrix.get(i, i));
        }
    }

    #[test]
    fn exact_operator_counts_and_matches() {
        let src = spd_source(10, 10.0, 7);
        let op = ExactOperator::new(&src);
        let x = Vector::standard_normal(10, 8);
        let y = op.apply(&x).unwrap();
        assert_eq!(y, src.matvec(&x));
        assert_eq!(op.mvm_count(), 1);
        assert_eq!(op.programming_passes(), 0);
    }

    #[test]
    fn solve_system_exact_cg_machine_precision() {
        let src = spd_source(32, 100.0, 9);
        let x_star = Vector::standard_normal(32, 10);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(&src);
        let opts = IterOptions::default()
            .with_tol(1e-9)
            .with_max_iters(500)
            .with_refinements(0);
        let out = solve_system(&op, Some(&src), &b, &opts).unwrap();
        // The verdict is the *true* residual; allow recurrence-vs-true
        // drift at the boundary but demand near-machine accuracy.
        assert!(out.rel_residual <= 1e-8, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-5, "{err}");
        assert_eq!(out.mvms, out.iterations as u64);
    }

    #[test]
    fn refinement_with_exact_inner_converges_fast() {
        // With an exact operator the first correction is already (near)
        // exact, so refinement terminates in a couple of outer steps.
        let src = spd_source(24, 50.0, 11);
        let x_star = Vector::standard_normal(24, 12);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(&src);
        let opts = IterOptions::default()
            .with_tol(1e-8)
            .with_inner_tol(1e-3)
            .with_max_iters(200)
            .with_refinements(20);
        let out = solve_system(&op, Some(&src), &b, &opts).unwrap();
        assert!(out.converged);
        assert!(out.rel_residual <= 1e-8);
        assert!(out.refinements <= 10, "{}", out.refinements);
        // History holds the exact outer residuals, strictly improving.
        assert!(out.history.first().unwrap() > out.history.last().unwrap());
    }

    #[test]
    fn zero_rhs_is_trivial() {
        let src = spd_source(8, 10.0, 13);
        let op = ExactOperator::new(&src);
        let out =
            solve_system(&op, Some(&src), &Vector::zeros(8), &IterOptions::default()).unwrap();
        assert!(out.converged);
        assert_eq!(out.mvms, 0);
        assert_eq!(out.x, Vector::zeros(8));
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let src = spd_source(8, 10.0, 14);
        let op = ExactOperator::new(&src);
        let bad = Vector::zeros(5);
        assert!(solve_system(&op, Some(&src), &bad, &IterOptions::default()).is_err());
    }

    #[test]
    fn plane_operator_matches_dedicated_session_bit_exact() {
        use crate::config::{SolveOptions, SystemConfig};
        use crate::device::materials::Material;
        use crate::runtime::native::NativeBackend;
        use crate::solver::Meliso;
        use std::sync::Arc;

        let config = SystemConfig::single_mca(64);
        let opts = SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_wv_iters(3)
            .with_seed(42);
        let src_a = crate::matrices::registry::build("spd64").unwrap();
        let src_b = crate::matrices::registry::build("spdill64").unwrap();
        let x_star = Vector::standard_normal(64, 21);
        let ba = src_a.matvec(&x_star);
        let bb = src_b.matvec(&x_star);
        let iter_opts = IterOptions::default()
            .with_tol(1e-4)
            .with_max_iters(60)
            .with_inner_tol(1e-2)
            .with_refinements(25);

        // Dedicated sessions (one plane per operand), via the front door.
        let solver = Meliso::with_backend(config, opts.clone(), Arc::new(NativeBackend::new()));
        let ded_a = solver.solve_system(src_a.clone(), &ba, &iter_opts).unwrap();
        let ded_b = solver.solve_system(src_b.clone(), &bb, &iter_opts).unwrap();

        // Both operands resident on ONE plane, solved through
        // PlaneOperators: bit-identical solutions.
        let plane = PlaneHandle::build(
            src_a.as_ref(),
            &config,
            &opts,
            Arc::new(NativeBackend::new()),
        )
        .unwrap();
        let op_a = PlaneOperator::program(&plane, src_a.as_ref()).unwrap();
        let op_b = PlaneOperator::program(&plane, src_b.as_ref()).unwrap();
        assert_eq!(plane.resident_operands(), 2);
        let out_a = solve_system(&op_a, Some(src_a.as_ref()), &ba, &iter_opts).unwrap();
        let out_b = solve_system(&op_b, Some(src_b.as_ref()), &bb, &iter_opts).unwrap();
        assert_eq!(out_a.x, ded_a.x, "operand A diverged on the shared plane");
        assert_eq!(out_b.x, ded_b.x, "operand B diverged on the shared plane");
        assert_eq!(op_a.programming_passes(), 1);
        assert!(op_a.mvm_count() > 0);
        // Dropping an operator evicts its residency.
        drop(op_a);
        assert_eq!(plane.resident_operands(), 1);
    }

    #[test]
    fn jacobi_without_source_is_clean_error() {
        let src = spd_source(8, 10.0, 15);
        let op = ExactOperator::new(&src);
        let b = Vector::standard_normal(8, 16);
        let opts = IterOptions::default().with_method(Method::Jacobi);
        let err = solve_system(&op, None, &b, &opts).unwrap_err();
        assert!(err.contains("diagonal"), "{err}");
    }
}
