//! Stationary iterations: Jacobi and damped Richardson.
//!
//! One MVM per sweep, f64 host-side update.  These converge only for
//! contractive iteration matrices (diagonally dominant operands for
//! Jacobi, spectrum inside the ω-disc for Richardson) — the registry's
//! `iperturb66` and the banded operands qualify — but where they apply
//! they are the cheapest possible use of a resident crossbar: no inner
//! products, no basis storage, just repeated reads.

use super::{IterationOutcome, MvmOperator};
use crate::linalg::Vector;

/// Jacobi sweeps `x ← x + D⁻¹(b − Ax)` from `x₀ = 0`.
pub fn jacobi(
    op: &dyn MvmOperator,
    diag: &Vector,
    b: &Vector,
    tol: f64,
    max_iters: usize,
) -> Result<IterationOutcome, String> {
    let n = b.len();
    if diag.len() != n {
        return Err(format!(
            "diagonal has length {}, b has length {n}",
            diag.len()
        ));
    }
    if let Some(i) = diag.data().iter().position(|v| *v == 0.0) {
        return Err(format!("jacobi needs a nonzero diagonal (row {i} is zero)"));
    }
    sweep(op, b, tol, max_iters, |x, r| {
        for ((xi, ri), di) in x.data_mut().iter_mut().zip(r.data()).zip(diag.data()) {
            *xi += ri / di;
        }
    })
}

/// Damped Richardson sweeps `x ← x + ω(b − Ax)` from `x₀ = 0`.
pub fn richardson(
    op: &dyn MvmOperator,
    omega: f64,
    b: &Vector,
    tol: f64,
    max_iters: usize,
) -> Result<IterationOutcome, String> {
    if omega <= 0.0 || !omega.is_finite() {
        return Err(format!("richardson needs a positive omega, got {omega}"));
    }
    sweep(op, b, tol, max_iters, |x, r| x.axpy(omega, r))
}

/// Shared sweep driver: `update` folds the current residual into `x`.
fn sweep(
    op: &dyn MvmOperator,
    b: &Vector,
    tol: f64,
    max_iters: usize,
    mut update: impl FnMut(&mut Vector, &Vector),
) -> Result<IterationOutcome, String> {
    let n = b.len();
    let bnorm = b.norm_l2();
    let mut x = Vector::zeros(n);
    let mut history = Vec::new();
    if bnorm == 0.0 {
        history.push(0.0);
        return Ok(IterationOutcome {
            x,
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history,
        });
    }
    let mut r = b.clone();
    let mut rel = 1.0;
    history.push(rel);
    let mut converged = rel <= tol;
    let mut iterations = 0;
    let mut prev = f64::INFINITY;
    while !converged && iterations < max_iters {
        update(&mut x, &r);
        let ax = op.apply(&x)?;
        iterations += 1;
        r = b.sub(&ax);
        rel = r.norm_l2() / bnorm;
        history.push(rel);
        if rel <= tol {
            converged = true;
            break;
        }
        // Divergence guard: stationary methods on the wrong operand blow
        // up geometrically — stop before the iterate overflows.
        if !rel.is_finite() || rel > 1e3 || (rel > prev * 4.0 && rel > 1.0) {
            break;
        }
        prev = rel;
    }
    Ok(IterationOutcome {
        x,
        iterations,
        converged,
        rel_residual: rel,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::{diagonal, ExactOperator};
    use crate::linalg::Matrix;
    use crate::matrices::registry;
    use crate::matrices::DenseSource;

    #[test]
    fn jacobi_converges_on_iperturb() {
        // Iperturb is a perturbed identity: the Jacobi iteration matrix
        // has spectral radius ≈ 0.1, so convergence is geometric.
        let src = registry::build("iperturb66").unwrap();
        let x_star = Vector::standard_normal(66, 3);
        let b = src.matvec(&x_star);
        let d = diagonal(src.as_ref());
        let op = ExactOperator::new(src.as_ref());
        let out = jacobi(&op, &d, &b, 1e-9, 200).unwrap();
        assert!(out.converged, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-6, "{err}");
        assert!(out.iterations < 100, "{}", out.iterations);
    }

    #[test]
    fn richardson_converges_on_iperturb() {
        let src = registry::build("iperturb66").unwrap();
        let x_star = Vector::standard_normal(66, 5);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(src.as_ref());
        let out = richardson(&op, 1.0, &b, 1e-9, 200).unwrap();
        assert!(out.converged, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-6, "{err}");
    }

    #[test]
    fn divergence_is_cut_short() {
        // Richardson with a large ω on a spectrum ≫ 1 diverges; the guard
        // must stop the sweep long before max_iters.
        let mut a = Matrix::identity(8);
        for i in 0..8 {
            a.set(i, i, 10.0);
        }
        let src = DenseSource::new(a);
        let b = Vector::standard_normal(8, 7);
        let op = ExactOperator::new(&src);
        let out = richardson(&op, 1.0, &b, 1e-9, 10_000).unwrap();
        assert!(!out.converged);
        assert!(out.iterations < 100, "{}", out.iterations);
        assert!(out.x.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn jacobi_rejects_zero_diagonal() {
        let src = DenseSource::new(Matrix::identity(4));
        let op = ExactOperator::new(&src);
        let d = Vector::zeros(4);
        let b = Vector::standard_normal(4, 9);
        assert!(jacobi(&op, &d, &b, 1e-6, 10).is_err());
    }

    #[test]
    fn richardson_rejects_bad_omega() {
        let src = DenseSource::new(Matrix::identity(4));
        let op = ExactOperator::new(&src);
        let b = Vector::standard_normal(4, 11);
        assert!(richardson(&op, 0.0, &b, 1e-6, 10).is_err());
        assert!(richardson(&op, f64::NAN, &b, 1e-6, 10).is_err());
    }
}
