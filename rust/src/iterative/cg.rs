//! Conjugate gradient for SPD operands.
//!
//! Textbook Hestenes–Stiefel with every scalar (α, β, residual norms)
//! computed f64 host-side; the only device work is the one `A·p` product
//! per iteration.  On a noisy operator the recurrence residual keeps
//! contracting while the *true* residual floors at the device error —
//! which is exactly what the refinement loop in [`crate::iterative`]
//! exploits: it only needs each inner CG run to beat relative error one.

use super::{IterationOutcome, MvmOperator};
use crate::linalg::Vector;

/// Solve `Ax = b` from `x₀ = 0` to relative (recurrence) residual `tol`
/// within `max_iters` MVMs.
///
/// Breakdown guard: a non-positive or non-finite curvature `pᵀAp` —
/// indefinite operand or noise swamping the search direction — stops the
/// iteration at the best iterate so far instead of stepping on garbage.
pub fn solve(
    op: &dyn MvmOperator,
    b: &Vector,
    tol: f64,
    max_iters: usize,
) -> Result<IterationOutcome, String> {
    let n = b.len();
    let bnorm = b.norm_l2();
    let mut x = Vector::zeros(n);
    let mut history = Vec::new();
    if bnorm == 0.0 {
        history.push(0.0);
        return Ok(IterationOutcome {
            x,
            iterations: 0,
            converged: true,
            rel_residual: 0.0,
            history,
        });
    }
    let mut r = b.clone();
    let mut p = r.clone();
    let mut rs = r.dot(&r);
    let mut rel = rs.sqrt() / bnorm;
    history.push(rel);
    let mut converged = rel <= tol;
    let mut iterations = 0;
    while !converged && iterations < max_iters {
        let ap = op.apply(&p)?;
        iterations += 1;
        let pap = p.dot(&ap);
        if pap <= 0.0 || !pap.is_finite() {
            break;
        }
        let alpha = rs / pap;
        x.axpy(alpha, &p);
        r.axpy(-alpha, &ap);
        let rs_new = r.dot(&r);
        rel = rs_new.sqrt() / bnorm;
        history.push(rel);
        if rel <= tol {
            converged = true;
            break;
        }
        let beta = rs_new / rs;
        rs = rs_new;
        p.scale(beta);
        p.add_assign(&r);
    }
    Ok(IterationOutcome {
        x,
        iterations,
        converged,
        rel_residual: rel,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iterative::ExactOperator;
    use crate::linalg::Matrix;
    use crate::matrices::generators;
    use crate::matrices::DenseSource;

    #[test]
    fn converges_on_spd_to_machine_precision() {
        let n = 40;
        let src = DenseSource::new(generators::dense_spd_with_condition(n, 5.0, 200.0, 6, 3));
        let x_star = Vector::standard_normal(n, 4);
        let b = src.matvec(&x_star);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-12, 400).unwrap();
        assert!(out.converged, "rel {}", out.rel_residual);
        let err = out.x.sub(&x_star).norm_l2() / x_star.norm_l2();
        assert!(err < 1e-8, "{err}");
        // History is recorded per iteration and ends under tolerance.
        assert_eq!(out.history.len(), out.iterations + 1);
        assert!(*out.history.last().unwrap() <= 1e-12);
    }

    #[test]
    fn iteration_count_scales_with_sqrt_kappa() {
        let n = 48;
        let easy = DenseSource::new(generators::dense_spd_with_condition(n, 5.0, 4.0, 6, 5));
        let hard = DenseSource::new(generators::dense_spd_with_condition(n, 5.0, 4000.0, 6, 5));
        let b = Vector::standard_normal(n, 6);
        let easy_out = solve(&ExactOperator::new(&easy), &b, 1e-8, 400).unwrap();
        let hard_out = solve(&ExactOperator::new(&hard), &b, 1e-8, 400).unwrap();
        assert!(easy_out.converged && hard_out.converged);
        assert!(
            easy_out.iterations < hard_out.iterations,
            "{} vs {}",
            easy_out.iterations,
            hard_out.iterations
        );
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let src = DenseSource::new(Matrix::identity(8));
        let op = ExactOperator::new(&src);
        let out = solve(&op, &Vector::zeros(8), 1e-10, 10).unwrap();
        assert!(out.converged);
        assert_eq!(out.iterations, 0);
    }

    #[test]
    fn budget_exhaustion_reports_not_converged() {
        let n = 32;
        let src = DenseSource::new(generators::dense_spd_with_condition(n, 5.0, 1e4, 6, 7));
        let b = Vector::standard_normal(n, 8);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-14, 3).unwrap();
        assert!(!out.converged);
        assert_eq!(out.iterations, 3);
        assert!(out.rel_residual > 0.0);
    }

    #[test]
    fn indefinite_operand_breaks_down_cleanly() {
        // A negative-definite operand flips the curvature sign on the
        // first step; the guard must stop rather than diverge.
        let mut a = Matrix::identity(6);
        for i in 0..6 {
            a.set(i, i, -1.0);
        }
        let src = DenseSource::new(a);
        let b = Vector::standard_normal(6, 9);
        let op = ExactOperator::new(&src);
        let out = solve(&op, &b, 1e-10, 50).unwrap();
        assert!(!out.converged);
        assert!(out.iterations <= 1);
    }
}
