//! Matrix substrate: the benchmark operands (DESIGN.md S4).
//!
//! The paper draws its operands from the SuiteSparse collection; this image
//! has no network access, so [`generators`] synthesizes stand-ins matching
//! each matrix's documented dimension, spectral norm, condition number and
//! sparsity (paper Table 2), and [`registry`] names them.  Matrices at and
//! above 8127² are represented *procedurally* ([`BandedSource`]) so the
//! 65,025² strong-scaling point streams tile-by-tile instead of
//! materializing ~34 GB of dense data — mirroring how the real system never
//! holds more than one tile per MCA.
//!
//! Real-world sparsity arrives through [`sparse::CsrSource`]: a CSR
//! operand assembled from triplets or a Matrix-Market file
//! ([`market`]), whose tight structural queries give irregular patterns
//! (arrowhead, power-law, block-diagonal) the same O(occupied-chunks)
//! planning that [`BandedSource`] gets.  The registry serves file-backed
//! operands under `mtx:<path>` (or any name ending in `.mtx`).

pub mod generators;
pub mod market;
pub mod registry;
pub mod sparse;

pub use sparse::CsrSource;

use crate::linalg::{Matrix, Vector};

/// A matrix operand that can be streamed tile-by-tile.
///
/// Both the virtualization layer (chunk extraction) and the ground-truth
/// pass (exact `f64` matvec) work through this interface, so dense and
/// procedural operands are interchangeable everywhere.
pub trait MatrixSource: Send + Sync {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;

    /// Extract block `[r0..r0+h, c0..c0+w)`, zero-padded at the edges.
    fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix;

    /// Exact `f64` matvec (ground truth `b = Ax`).
    fn matvec(&self, x: &Vector) -> Vector;

    /// Conservative test: `true` only if the block is certainly all-zero
    /// (enables the execution plane's sparsity-aware chunk skipping).
    fn block_is_zero(&self, _r0: usize, _c0: usize, _h: usize, _w: usize) -> bool {
        false
    }

    /// Conservative column span `[lo, hi)` that may hold nonzeros within
    /// rows `[r0, r0 + rows)`.  Lets chunk planning
    /// ([`ChunkPlan::nonzero_chunks`](crate::virtualization::ChunkPlan::nonzero_chunks))
    /// enumerate occupied blocks without walking the full `O(grid²)` grid.
    /// The default spans every column (no information); an empty span
    /// (`lo >= hi`) means the rows are certainly all-zero.
    fn occupied_cols(&self, _r0: usize, _rows: usize) -> (usize, usize) {
        (0, self.ncols())
    }

    /// Occupied chunk-column *set* for rows `[r0, r0 + rows)` at chunk
    /// width `tile`: a sorted, deduplicated list of chunk-column indices
    /// (`j / tile`) that may hold nonzeros.  Unlike
    /// [`occupied_cols`](Self::occupied_cols), a set can have interior
    /// gaps, so patterns like arrowheads and block diagonals — whose spans
    /// cover hole chunks between the first and last occupied column — plan
    /// exactly their occupied chunks.  The default derives the set from
    /// the span (no gap information); sources with exact structure
    /// (e.g. [`CsrSource`]) override it.
    fn occupied_col_chunks(&self, r0: usize, rows: usize, tile: usize) -> Vec<usize> {
        if tile == 0 {
            return Vec::new();
        }
        let (lo, hi) = self.occupied_cols(r0, rows);
        if lo >= hi {
            return Vec::new();
        }
        (lo / tile..crate::util::ceil_div(hi, tile)).collect()
    }

    /// Upper bound on |entries| (used for conductance scaling decisions).
    fn max_abs(&self) -> f64;
}

/// Dense in-memory operand.
pub struct DenseSource {
    pub matrix: Matrix,
}

impl DenseSource {
    pub fn new(matrix: Matrix) -> Self {
        Self { matrix }
    }
}

impl MatrixSource for DenseSource {
    fn nrows(&self) -> usize {
        self.matrix.nrows()
    }

    fn ncols(&self) -> usize {
        self.matrix.ncols()
    }

    fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        self.matrix.block_padded(r0, c0, h, w)
    }

    fn matvec(&self, x: &Vector) -> Vector {
        self.matrix.matvec(x)
    }

    fn max_abs(&self) -> f64 {
        self.matrix.max_abs()
    }
}

/// Procedural banded operand: entries are a deterministic function of
/// (i, j) inside a band of half-width `band`; zero outside.
///
/// `diag(i)` sets the diagonal profile (condition-number control) and
/// off-diagonal entries are pseudo-random, symmetric, with amplitude
/// `off_amp` decaying away from the diagonal.
pub struct BandedSource {
    pub n: usize,
    pub band: usize,
    pub d_max: f64,
    /// Geometric decay ratio across the diagonal: d(i) spans
    /// `d_max .. d_max/kappa_target`.
    pub kappa_target: f64,
    pub off_amp: f64,
    pub seed: u64,
}

impl BandedSource {
    pub fn new(n: usize, band: usize, d_max: f64, kappa_target: f64, off_amp: f64, seed: u64) -> Self {
        assert!(n > 1 && kappa_target >= 1.0);
        Self {
            n,
            band,
            d_max,
            kappa_target,
            off_amp,
            seed,
        }
    }

    #[inline]
    fn diag(&self, i: usize) -> f64 {
        // Geometric interpolation d_max -> d_max / kappa across rows.
        let t = i as f64 / (self.n - 1) as f64;
        self.d_max * self.kappa_target.powf(-t)
    }

    /// Deterministic symmetric pseudo-random off-diagonal in [-1, 1].
    #[inline]
    fn off_unit(&self, i: usize, j: usize) -> f64 {
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        let mut h = self.seed ^ 0x9E3779B97F4A7C15;
        for v in [a as u64, b as u64] {
            h ^= v.wrapping_mul(0xBF58476D1CE4E5B9);
            h = h.rotate_left(27).wrapping_mul(0x94D049BB133111EB);
        }
        h ^= h >> 31;
        // Map to [-1, 1).
        (h >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0
    }

    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        if i >= self.n || j >= self.n {
            return 0.0;
        }
        let dist = i.abs_diff(j);
        if dist > self.band {
            return 0.0;
        }
        if dist == 0 {
            return self.diag(i);
        }
        // Decay with distance keeps the matrix diagonally dominant enough
        // for the condition number to track the diagonal profile.
        let decay = 1.0 - dist as f64 / (self.band + 1) as f64;
        let local_scale = self.diag(i).min(self.diag(j));
        self.off_amp * local_scale * decay * self.off_unit(i, j)
    }
}

impl MatrixSource for BandedSource {
    fn nrows(&self) -> usize {
        self.n
    }

    fn ncols(&self) -> usize {
        self.n
    }

    fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        let mut out = Matrix::zeros(h, w);
        for i in 0..h {
            let gi = r0 + i;
            if gi >= self.n {
                break;
            }
            // Only touch columns within the band.
            let lo = gi.saturating_sub(self.band).max(c0);
            let hi = (gi + self.band + 1).min(self.n).min(c0 + w);
            if lo >= hi {
                continue;
            }
            let row = out.row_mut(i);
            for gj in lo..hi {
                row[gj - c0] = self.entry(gi, gj);
            }
        }
        out
    }

    fn matvec(&self, x: &Vector) -> Vector {
        assert_eq!(x.len(), self.n);
        let mut out = vec![0.0; self.n];
        for (i, o) in out.iter_mut().enumerate() {
            let lo = i.saturating_sub(self.band);
            let hi = (i + self.band + 1).min(self.n);
            let mut acc = 0.0;
            for j in lo..hi {
                acc += self.entry(i, j) * x.get(j);
            }
            *o = acc;
        }
        Vector::from_vec(out)
    }

    fn block_is_zero(&self, r0: usize, c0: usize, h: usize, w: usize) -> bool {
        if r0 >= self.n || c0 >= self.n {
            return true;
        }
        // The block is zero iff it does not intersect the band
        // |i - j| <= band for any (i, j) in the block.
        let r1 = (r0 + h - 1).min(self.n - 1) as i64;
        let c1 = (c0 + w - 1).min(self.n - 1) as i64;
        let (r0, c0) = (r0 as i64, c0 as i64);
        let band = self.band as i64;
        // min over block of (i - j) is r0 - c1; max is r1 - c0.
        r0 - c1 > band || c0 - r1 > band
    }

    fn occupied_cols(&self, r0: usize, rows: usize) -> (usize, usize) {
        if r0 >= self.n || rows == 0 {
            return (0, 0);
        }
        let last = (r0 + rows - 1).min(self.n - 1);
        (
            r0.saturating_sub(self.band),
            (last + self.band + 1).min(self.n),
        )
    }

    fn max_abs(&self) -> f64 {
        self.d_max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_source_roundtrip() {
        let m = Matrix::standard_normal(10, 10, 1);
        let s = DenseSource::new(m.clone());
        let b = s.block(2, 3, 4, 4);
        for i in 0..4 {
            for j in 0..4 {
                if 2 + i < 10 && 3 + j < 10 {
                    assert_eq!(b.get(i, j), m.get(2 + i, 3 + j));
                }
            }
        }
        let x = Vector::standard_normal(10, 2);
        assert_eq!(s.matvec(&x), m.matvec(&x));
    }

    #[test]
    fn banded_block_matches_entry() {
        let s = BandedSource::new(100, 5, 2.0, 50.0, 0.3, 9);
        let b = s.block(40, 38, 8, 8);
        for i in 0..8 {
            for j in 0..8 {
                assert_eq!(b.get(i, j), s.entry(40 + i, 38 + j));
            }
        }
    }

    #[test]
    fn banded_is_symmetric() {
        let s = BandedSource::new(64, 4, 1.0, 10.0, 0.2, 3);
        for i in 0..64 {
            for j in 0..64 {
                assert_eq!(s.entry(i, j), s.entry(j, i));
            }
        }
    }

    #[test]
    fn banded_matvec_matches_dense() {
        let s = BandedSource::new(80, 6, 1.5, 20.0, 0.25, 11);
        let dense = s.block(0, 0, 80, 80);
        let x = Vector::standard_normal(80, 4);
        let got = s.matvec(&x);
        let want = dense.matvec(&x);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn banded_zero_block_detection() {
        let s = BandedSource::new(1000, 8, 1.0, 10.0, 0.2, 5);
        assert!(s.block_is_zero(0, 500, 32, 32));
        assert!(s.block_is_zero(500, 0, 32, 32));
        assert!(!s.block_is_zero(500, 500, 32, 32));
        // Conservative at the band edge.
        assert!(!s.block_is_zero(0, 32, 32, 32)); // touches |i-j|=1..?
                                                  // blocks beyond the matrix are zero
        assert!(s.block_is_zero(2000, 0, 32, 32));
    }

    #[test]
    fn banded_zero_block_agrees_with_block() {
        let s = BandedSource::new(300, 10, 1.0, 5.0, 0.3, 7);
        for (r0, c0) in [(0usize, 0usize), (0, 64), (64, 0), (128, 160), (256, 280)] {
            if s.block_is_zero(r0, c0, 32, 32) {
                let b = s.block(r0, c0, 32, 32);
                assert!(b.data().iter().all(|&v| v == 0.0), "({r0},{c0})");
            }
        }
    }

    #[test]
    fn occupied_cols_bounds_the_band() {
        let s = BandedSource::new(1000, 8, 1.0, 10.0, 0.2, 5);
        assert_eq!(s.occupied_cols(0, 32), (0, 40));
        assert_eq!(s.occupied_cols(500, 32), (492, 540));
        assert_eq!(s.occupied_cols(992, 32), (984, 1000));
        // Past the matrix: certainly empty.
        let (lo, hi) = s.occupied_cols(2000, 32);
        assert!(lo >= hi);
        // The span really covers every nonzero column of those rows.
        for r0 in [0usize, 480, 960] {
            let (lo, hi) = s.occupied_cols(r0, 32);
            for i in r0..(r0 + 32).min(1000) {
                for j in 0..1000 {
                    if s.entry(i, j) != 0.0 {
                        assert!(j >= lo && j < hi, "({i},{j}) outside [{lo},{hi})");
                    }
                }
            }
        }
    }

    #[test]
    fn dense_occupied_cols_spans_everything() {
        let m = Matrix::standard_normal(10, 10, 1);
        let s = DenseSource::new(m);
        assert_eq!(s.occupied_cols(0, 4), (0, 10));
    }

    #[test]
    fn default_occupied_col_chunks_covers_the_span() {
        let s = BandedSource::new(1000, 8, 1.0, 10.0, 0.2, 5);
        // Span [492, 540) at tile 32 -> chunk columns 15..17 (inclusive).
        assert_eq!(s.occupied_col_chunks(500, 32, 32), vec![15, 16]);
        assert_eq!(s.occupied_col_chunks(0, 32, 32), vec![0, 1]);
        // Empty rows yield an empty set, and tile 0 never divides by zero.
        assert!(s.occupied_col_chunks(2000, 32, 32).is_empty());
        assert!(s.occupied_col_chunks(0, 32, 0).is_empty());
        // Dense sources cover every chunk column.
        let d = DenseSource::new(Matrix::standard_normal(10, 10, 1));
        assert_eq!(d.occupied_col_chunks(0, 4, 4), vec![0, 1, 2]);
    }

    #[test]
    fn banded_diag_profile_spans_kappa() {
        let s = BandedSource::new(1000, 4, 8.0, 100.0, 0.1, 1);
        assert!((s.entry(0, 0) - 8.0).abs() < 1e-12);
        assert!((s.entry(999, 999) - 0.08).abs() < 1e-6);
    }
}
