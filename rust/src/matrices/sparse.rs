//! General sparse operands in Compressed Sparse Row form.
//!
//! The paper evaluates MELISO+ on SuiteSparse operands whose sparsity is
//! *irregular* — arrowheads, power-law degree profiles, block structure —
//! not just bands.  [`CsrSource`] is the [`MatrixSource`] that carries
//! such patterns end-to-end: it implements an exact `f64` [`matvec`],
//! zero-padded [`block`] extraction in O(nnz in the block's rows), and
//! *tight* [`block_is_zero`] / [`occupied_cols`] answers derived from the
//! row-pointer/column-index structure, so the execution plane's streaming
//! planning ([`ChunkPlan::nonzero_chunks`]) dispatches exactly the
//! occupied chunks — the same O(occupied-chunks) treatment
//! [`BandedSource`](super::BandedSource) gets, now for arbitrary patterns.
//!
//! Construct one [`from_triplets`] (any order, duplicates summed — the
//! SuiteSparse assembly convention) or [`from_mtx`] (streaming over the
//! Matrix-Market reader in [`super::market`]; memory stays O(nnz), never
//! O(m·n)).
//!
//! ```
//! use meliso::matrices::{sparse::CsrSource, MatrixSource};
//! use meliso::linalg::Vector;
//!
//! // A 3x4 operand with one empty row, from unordered triplets.
//! let a = CsrSource::from_triplets(
//!     3,
//!     4,
//!     &[(2, 3, 5.0), (0, 1, 2.0), (0, 1, 1.0)], // (0,1) duplicates sum to 3.0
//! )
//! .unwrap();
//! assert_eq!(a.nnz(), 2);
//! let y = a.matvec(&Vector::from_vec(vec![1.0, 10.0, 0.0, 2.0]));
//! assert_eq!(y.data(), &[30.0, 0.0, 10.0]);
//! // Tight structural answers: row 1 is empty, the (0,0) tile is occupied.
//! assert_eq!(a.occupied_cols(1, 1), (0, 0));
//! assert!(!a.block_is_zero(0, 0, 2, 2));
//! assert!(a.block_is_zero(0, 2, 2, 2));
//! ```
//!
//! [`matvec`]: CsrSource::matvec
//! [`block`]: CsrSource::block
//! [`block_is_zero`]: CsrSource::block_is_zero
//! [`occupied_cols`]: CsrSource::occupied_cols
//! [`from_triplets`]: CsrSource::from_triplets
//! [`from_mtx`]: CsrSource::from_mtx
//! [`ChunkPlan::nonzero_chunks`]: crate::virtualization::ChunkPlan::nonzero_chunks

use super::market::{self, MarketError};
use super::MatrixSource;
use crate::linalg::{Matrix, Vector};
use std::path::Path;

/// A sparse matrix operand in CSR (compressed sparse row) format.
///
/// Invariants maintained by every constructor:
/// * `row_ptr.len() == nrows + 1`, monotone, `row_ptr[nrows] == nnz`;
/// * within each row, column indices are strictly increasing (duplicates
///   were summed at assembly);
/// * no explicit zeros are stored (entries that assemble to exactly `0.0`
///   are dropped), so the structural queries are *tight*: `block_is_zero`
///   is exact, not merely conservative, and `occupied_cols` returns the
///   smallest span covering the rows' nonzeros.
pub struct CsrSource {
    nrows: usize,
    ncols: usize,
    row_ptr: Vec<usize>,
    col_idx: Vec<usize>,
    vals: Vec<f64>,
    max_abs: f64,
}

impl CsrSource {
    /// Assemble from coordinate triplets `(row, col, value)` in any order.
    ///
    /// Duplicate coordinates are **summed** in their given order (the
    /// SuiteSparse assembly convention, bit-identical to the dense
    /// reader's sequential accumulation); entries that sum to exactly
    /// `0.0` are dropped so the stored pattern stays tight.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Result<CsrSource, String> {
        if nrows == 0 || ncols == 0 {
            return Err(format!("empty operand shape {nrows}x{ncols}"));
        }
        for (k, &(i, j, _)) in triplets.iter().enumerate() {
            if i >= nrows || j >= ncols {
                return Err(format!(
                    "triplet {k}: index ({i},{j}) out of range for a {nrows}x{ncols} operand \
                     (indices are 0-based)"
                ));
            }
        }
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        // Stable sort: duplicates keep their input order, so summation
        // order (and therefore the f64 result) matches a sequential
        // dense assembly of the same stream.
        sorted.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

        let mut row_ptr = vec![0usize; nrows + 1];
        let mut col_idx = Vec::with_capacity(sorted.len());
        let mut vals: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut k = 0usize;
        while k < sorted.len() {
            let (i, j, mut v) = sorted[k];
            k += 1;
            while k < sorted.len() && sorted[k].0 == i && sorted[k].1 == j {
                v += sorted[k].2;
                k += 1;
            }
            if v != 0.0 {
                row_ptr[i + 1] += 1;
                col_idx.push(j);
                vals.push(v);
            }
        }
        for i in 0..nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let max_abs = vals.iter().fold(0.0f64, |m, v| m.max(v.abs()));
        Ok(CsrSource {
            nrows,
            ncols,
            row_ptr,
            col_idx,
            vals,
            max_abs,
        })
    }

    /// Load a Matrix-Market `.mtx` file as a CSR operand.
    ///
    /// Streams through [`market::read_mtx_triplets`]: memory is O(nnz)
    /// end-to-end (the dense reader's O(m·n) materialization never
    /// happens), symmetric files are mirrored, and duplicate coordinates
    /// are summed exactly as the dense path would.
    pub fn from_mtx(path: &Path) -> Result<CsrSource, MarketError> {
        let data = market::read_mtx_triplets(path)?;
        CsrSource::from_triplets(data.rows, data.cols, &data.entries)
            .map_err(market::MarketError::Format)
    }

    /// Stored (structural) nonzero count.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// nnz / (m·n).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.nrows as f64 * self.ncols as f64)
    }

    /// One row's column indices and values.
    pub fn row(&self, i: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    /// Entry lookup (binary search within the row; 0.0 off-pattern).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        if i >= self.nrows || j >= self.ncols {
            return 0.0;
        }
        let (cols, vals) = self.row(i);
        match cols.binary_search(&j) {
            Ok(k) => vals[k],
            Err(_) => 0.0,
        }
    }

    /// Materialize the full dense matrix — O(m·n) memory, deliberately
    /// explicit.  This is the only dense escape hatch; everything on the
    /// solve path streams tiles through [`MatrixSource::block`] instead.
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.nrows, self.ncols);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&j, &v) in cols.iter().zip(vals) {
                m.set(i, j, v);
            }
        }
        m
    }
}

impl MatrixSource for CsrSource {
    fn nrows(&self) -> usize {
        self.nrows
    }

    fn ncols(&self) -> usize {
        self.ncols
    }

    /// O(rows in block + nnz inside the block) + one binary search per
    /// row: never touches entries outside the requested rows.
    fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        let mut out = Matrix::zeros(h, w);
        let r_end = (r0.saturating_add(h)).min(self.nrows);
        for i in r0..r_end {
            let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let cols = &self.col_idx[lo..hi];
            let start = cols.partition_point(|&c| c < c0);
            let row_out = out.row_mut(i - r0);
            for (k, &j) in cols.iter().enumerate().skip(start) {
                if j >= c0 + w {
                    break;
                }
                row_out[j - c0] = self.vals[lo + k];
            }
        }
        out
    }

    fn matvec(&self, x: &Vector) -> Vector {
        assert_eq!(x.len(), self.ncols, "matvec dim mismatch");
        let xs = x.data();
        let mut out = vec![0.0; self.nrows];
        for (i, o) in out.iter_mut().enumerate() {
            let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.vals[k] * xs[self.col_idx[k]];
            }
            *o = acc;
        }
        Vector::from_vec(out)
    }

    /// Exact (not just conservative): constructors drop assembled zeros,
    /// so a block reads as zero iff no stored entry falls inside it.
    fn block_is_zero(&self, r0: usize, c0: usize, h: usize, w: usize) -> bool {
        if r0 >= self.nrows || c0 >= self.ncols {
            return true;
        }
        let r_end = (r0.saturating_add(h)).min(self.nrows);
        let c_end = c0.saturating_add(w);
        for i in r0..r_end {
            let cols = &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]];
            let start = cols.partition_point(|&c| c < c0);
            if start < cols.len() && cols[start] < c_end {
                return false;
            }
        }
        true
    }

    /// Tight span: the smallest `[lo, hi)` covering every stored nonzero
    /// of rows `[r0, r0+rows)` — O(rows) from the first/last column index
    /// of each row (columns are sorted within rows).
    fn occupied_cols(&self, r0: usize, rows: usize) -> (usize, usize) {
        if r0 >= self.nrows || rows == 0 {
            return (0, 0);
        }
        let r_end = (r0.saturating_add(rows)).min(self.nrows);
        let (mut lo, mut hi) = (usize::MAX, 0usize);
        for i in r0..r_end {
            let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
            if a < b {
                lo = lo.min(self.col_idx[a]);
                hi = hi.max(self.col_idx[b - 1] + 1);
            }
        }
        if lo == usize::MAX {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Exact occupied set: the chunk columns actually holding stored
    /// entries, sorted and deduplicated — O(nnz in rows).  Interior gaps
    /// (an arrowhead row chunk occupies column chunk 0 and its diagonal
    /// chunk, nothing between) disappear from planning entirely, where the
    /// span-based default would still enumerate every hole chunk just to
    /// discard it with a `block_is_zero` probe.
    fn occupied_col_chunks(&self, r0: usize, rows: usize, tile: usize) -> Vec<usize> {
        if r0 >= self.nrows || rows == 0 || tile == 0 {
            return Vec::new();
        }
        let r_end = (r0.saturating_add(rows)).min(self.nrows);
        let mut chunks: Vec<usize> =
            self.col_idx[self.row_ptr[r0]..self.row_ptr[r_end]]
                .iter()
                .map(|&j| j / tile)
                .collect();
        chunks.sort_unstable();
        chunks.dedup();
        chunks
    }

    fn max_abs(&self) -> f64 {
        self.max_abs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random sparse triplets, possibly with duplicates and empty rows.
    fn random_triplets(
        rng: &mut Rng,
        nrows: usize,
        ncols: usize,
        count: usize,
    ) -> Vec<(usize, usize, f64)> {
        (0..count)
            .map(|_| {
                (
                    rng.below(nrows),
                    rng.below(ncols),
                    rng.uniform_range(-2.0, 2.0),
                )
            })
            .collect()
    }

    fn dense_of(triplets: &[(usize, usize, f64)], m: usize, n: usize) -> Matrix {
        let mut d = Matrix::zeros(m, n);
        for &(i, j, v) in triplets {
            d.set(i, j, d.get(i, j) + v);
        }
        d
    }

    #[test]
    fn from_triplets_sums_duplicates_and_sorts() {
        let a = CsrSource::from_triplets(
            2,
            3,
            &[(1, 2, 1.0), (0, 1, 0.5), (1, 2, 2.0), (1, 0, -1.0)],
        )
        .unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(1, 2), 3.0);
        assert_eq!(a.get(0, 1), 0.5);
        assert_eq!(a.get(1, 0), -1.0);
        assert_eq!(a.get(0, 0), 0.0);
        let (cols, _) = a.row(1);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(a.max_abs(), 3.0);
    }

    #[test]
    fn assembled_zeros_are_dropped() {
        let a = CsrSource::from_triplets(2, 2, &[(0, 0, 1.5), (0, 0, -1.5), (1, 1, 2.0)]).unwrap();
        assert_eq!(a.nnz(), 1);
        assert!(a.block_is_zero(0, 0, 1, 1), "cancelled entry must read as structurally zero");
    }

    #[test]
    fn rejects_out_of_range_and_empty_shape() {
        assert!(CsrSource::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
        assert!(CsrSource::from_triplets(2, 2, &[(0, 2, 1.0)]).is_err());
        assert!(CsrSource::from_triplets(0, 2, &[]).is_err());
    }

    #[test]
    fn block_and_matvec_match_dense_reference() {
        let mut rng = Rng::new(0xC5);
        for case in 0..20 {
            let m = 1 + rng.below(40);
            let n = 1 + rng.below(40);
            let count = rng.below(3 * (m + n));
            let trip = random_triplets(&mut rng, m, n, count);
            let a = CsrSource::from_triplets(m, n, &trip).unwrap();
            let d = dense_of(&trip, m, n);
            // matvec agrees bit-for-bit in structure-free positions.
            let x = Vector::standard_normal(n, 1000 + case);
            let ya = a.matvec(&x);
            let yd = d.matvec(&x);
            for (g, w) in ya.data().iter().zip(yd.data()) {
                assert!((g - w).abs() < 1e-12, "case {case}");
            }
            // Blocks (including tail tiles past the edge) agree exactly.
            let probes = [
                (0, 0, 8, 8),
                (m / 2, n / 2, 16, 16),
                (m - 1, 0, 4, n + 3),
                (0, n - 1, m + 2, 4),
            ];
            for &(r0, c0, h, w) in &probes {
                let got = a.block(r0, c0, h, w);
                let want = d.block_padded(r0, c0, h, w);
                assert_eq!(got, want, "case {case} block ({r0},{c0},{h},{w})");
            }
        }
    }

    #[test]
    fn block_is_zero_is_exact() {
        let mut rng = Rng::new(0xC6);
        let (m, n) = (50, 37);
        let trip = random_triplets(&mut rng, m, n, 60);
        let a = CsrSource::from_triplets(m, n, &trip).unwrap();
        let d = dense_of(&trip, m, n);
        for r0 in (0..m + 8).step_by(7) {
            for c0 in (0..n + 8).step_by(5) {
                let structural = a.block_is_zero(r0, c0, 8, 8);
                let actual = d.block_padded(r0, c0, 8, 8).data().iter().all(|&v| v == 0.0);
                assert_eq!(structural, actual, "({r0},{c0})");
            }
        }
    }

    #[test]
    fn occupied_cols_is_tight() {
        let a =
            CsrSource::from_triplets(4, 100, &[(0, 7, 1.0), (0, 90, 2.0), (2, 40, -1.0)]).unwrap();
        assert_eq!(a.occupied_cols(0, 1), (7, 91));
        assert_eq!(a.occupied_cols(1, 1), (0, 0)); // empty row
        assert_eq!(a.occupied_cols(2, 2), (40, 41));
        assert_eq!(a.occupied_cols(0, 4), (7, 91));
        assert_eq!(a.occupied_cols(9, 3), (0, 0)); // past the matrix
    }

    #[test]
    fn occupied_col_chunks_has_interior_gaps() {
        // Arrowhead row chunk: entries in column chunk 0 and its diagonal
        // chunk only — the set skips the hole chunks between them that the
        // span-derived default would enumerate.
        let a = CsrSource::from_triplets(
            256,
            256,
            &[(128, 3, 1.0), (129, 130, 2.0), (135, 250, -1.0)],
        )
        .unwrap();
        assert_eq!(a.occupied_col_chunks(128, 32, 32), vec![0, 4, 7]);
        assert_eq!(a.occupied_col_chunks(128, 1, 32), vec![0]);
        assert_eq!(a.occupied_col_chunks(0, 32, 32), Vec::<usize>::new());
        assert_eq!(a.occupied_col_chunks(300, 32, 32), Vec::<usize>::new());
        // Duplicate chunk hits dedupe; result stays sorted.
        let b = CsrSource::from_triplets(4, 64, &[(0, 5, 1.0), (1, 7, 1.0), (2, 40, 1.0)]).unwrap();
        assert_eq!(b.occupied_col_chunks(0, 4, 16), vec![0, 2]);
    }

    #[test]
    fn to_dense_roundtrip() {
        let mut rng = Rng::new(0xC7);
        let trip = random_triplets(&mut rng, 12, 9, 25);
        let a = CsrSource::from_triplets(12, 9, &trip).unwrap();
        assert_eq!(a.to_dense(), dense_of(&trip, 12, 9));
        assert!((a.density() - a.nnz() as f64 / 108.0).abs() < 1e-15);
    }

    #[test]
    fn planning_walks_exactly_the_occupied_chunks() {
        use crate::virtualization::{ChunkPlan, SystemGeometry};
        // Arrowhead-ish irregular pattern: full first row + scattered tail.
        let n = 300;
        let mut trip: Vec<(usize, usize, f64)> = (0..n).map(|j| (0, j, 1.0)).collect();
        trip.extend((1..n).map(|i| (i, i, 2.0)));
        trip.push((250, 10, 1.0));
        let a = CsrSource::from_triplets(n, n, &trip).unwrap();
        let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 32), n, n);
        let tile = 32;
        let full: Vec<(usize, usize)> = plan
            .chunks()
            .filter(|c| !a.block_is_zero(c.row0, c.col0, tile, tile))
            .map(|c| (c.block_row, c.block_col))
            .collect();
        let streamed: Vec<(usize, usize)> = plan
            .nonzero_chunks(&a)
            .map(|c| (c.block_row, c.block_col))
            .collect();
        assert_eq!(full, streamed);
        // The irregular pattern occupies far fewer chunks than the grid.
        assert!(streamed.len() * 3 < plan.total_chunks(), "{}", streamed.len());
    }

    #[test]
    fn from_mtx_matches_dense_reader() {
        let mut p = std::env::temp_dir();
        p.push(format!("meliso_csr_mtx_{}", std::process::id()));
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 4.0\n2 2 5.0\n3 1 -1.0\n3 1 -0.5\n",
        )
        .unwrap();
        let a = CsrSource::from_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!((a.nrows(), a.ncols()), (3, 3));
        // duplicates summed, symmetry mirrored, diagonal not doubled.
        assert_eq!(a.get(2, 0), -1.5);
        assert_eq!(a.get(0, 2), -1.5);
        assert_eq!(a.get(0, 0), 4.0);
        assert_eq!(a.get(1, 1), 5.0);
        assert_eq!(a.nnz(), 4);
    }
}
