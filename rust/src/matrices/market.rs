//! MatrixMarket (.mtx) reader/writer.
//!
//! If a user drops *real* SuiteSparse files into `data/`, the CLI loads
//! them instead of the synthetic stand-ins (`--matrix path/to/file.mtx`);
//! the writer lets us cache generated operands for inspection.  Supports
//! the `matrix coordinate real {general|symmetric}` and `matrix array
//! real general` flavors.
//!
//! The reader follows the SuiteSparse conventions strictly: 1-based
//! indices are validated against the header dimensions, duplicate entries
//! are **summed** (assembled, as SuiteSparse defines them), and every
//! malformed entry is a [`MarketError::Format`] carrying its line number.
//! `pattern` and `complex` fields are rejected up front with an explicit
//! message instead of being misparsed as real data.
//!
//! The entry point is [`read_mtx_triplets`], which streams the file into
//! an O(nnz) coordinate list — feed it to
//! [`CsrSource::from_triplets`](super::sparse::CsrSource::from_triplets)
//! (or use [`CsrSource::from_mtx`](super::sparse::CsrSource::from_mtx)
//! directly).  When a dense copy is genuinely wanted, call
//! [`CsrSource::to_dense`](super::sparse::CsrSource::to_dense) explicitly;
//! the old `read_mtx` dense reader (deprecated in 0.3.0, O(m·n) memory
//! even for tiny-nnz files) was removed in 0.4.0.

use crate::linalg::Matrix;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

#[derive(Debug)]
pub enum MarketError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for MarketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MarketError::Io(e) => write!(f, "io error: {e}"),
            MarketError::Format(m) => write!(f, "matrixmarket format error: {m}"),
        }
    }
}

impl std::error::Error for MarketError {}

impl From<std::io::Error> for MarketError {
    fn from(e: std::io::Error) -> Self {
        MarketError::Io(e)
    }
}

fn ferr(msg: impl Into<String>) -> MarketError {
    MarketError::Format(msg.into())
}

/// Assembled coordinate stream of one `.mtx` file: dimensions plus
/// 0-based `(row, col, value)` entries in file order.
///
/// Symmetric files are mirrored here (each off-diagonal entry appears
/// twice, `(i, j)` then `(j, i)`); duplicate coordinates are **not**
/// summed yet — consumers assemble, preserving the SuiteSparse summation
/// order (see
/// [`CsrSource::from_triplets`](super::sparse::CsrSource::from_triplets)).
/// Explicitly-stored zeros (and `array`-format zeros) are dropped.
pub struct MtxData {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

/// Read a `.mtx` file into an O(nnz) triplet stream ([`MtxData`]).
pub fn read_mtx_triplets(path: &Path) -> Result<MtxData, MarketError> {
    let file = std::fs::File::open(path)?;
    let mut lines = BufReader::new(file).lines().enumerate();

    let header = lines
        .next()
        .ok_or_else(|| ferr("empty file"))?
        .1?
        .to_lowercase();
    if !header.starts_with("%%matrixmarket") {
        return Err(ferr("missing %%MatrixMarket header"));
    }
    let tokens: Vec<&str> = header.split_whitespace().collect();
    let coordinate = match tokens.get(2) {
        Some(&"coordinate") => true,
        Some(&"array") => false,
        other => return Err(ferr(format!("unsupported format {other:?}"))),
    };
    match tokens.get(3) {
        Some(&"real") | Some(&"integer") => {}
        Some(&"pattern") => {
            return Err(ferr(
                "line 1: `pattern` fields are not supported (no values to program onto \
                 conductances); convert to real first",
            ))
        }
        Some(&"complex") => {
            return Err(ferr(
                "line 1: `complex` fields are not supported (crossbar operands are real); \
                 take the real part or the modulus first",
            ))
        }
        other => return Err(ferr(format!("line 1: unsupported field {other:?}"))),
    }
    let symmetric = match tokens.get(4) {
        Some(&"general") | None => false,
        Some(&"symmetric") => true,
        other => return Err(ferr(format!("unsupported symmetry {other:?}"))),
    };

    // Skip comments, read the size line.
    let mut size_line = None;
    for (idx, line) in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some((idx + 1, t.to_string()));
        break;
    }
    let (size_lineno, size_line) = size_line.ok_or_else(|| ferr("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| {
            t.parse::<usize>()
                .map_err(|e| ferr(format!("line {size_lineno}: bad size: {e}")))
        })
        .collect::<Result<_, _>>()?;

    if coordinate {
        let (&rows, &cols, &nnz) = match dims.as_slice() {
            [r, c, n] => (r, c, n),
            _ => {
                return Err(ferr(format!(
                    "line {size_lineno}: coordinate size line must be `rows cols nnz`"
                )))
            }
        };
        let mut entries = Vec::with_capacity(if symmetric { 2 * nnz } else { nnz });
        let mut seen = 0usize;
        for (idx, line) in lines {
            let lineno = idx + 1;
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let i: usize = it
                .next()
                .ok_or_else(|| ferr(format!("line {lineno}: truncated entry")))?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad row index: {e}")))?;
            let j: usize = it
                .next()
                .ok_or_else(|| ferr(format!("line {lineno}: truncated entry")))?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad col index: {e}")))?;
            let v: f64 = it
                .next()
                .ok_or_else(|| {
                    ferr(format!(
                        "line {lineno}: missing value (pattern entries are not supported)"
                    ))
                })?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad value: {e}")))?;
            if it.next().is_some() {
                return Err(ferr(format!(
                    "line {lineno}: trailing tokens after `row col value`"
                )));
            }
            if i == 0 || j == 0 || i > rows || j > cols {
                return Err(ferr(format!(
                    "line {lineno}: index ({i},{j}) out of range for a {rows}x{cols} \
                     operand (indices are 1-based)"
                )));
            }
            // SuiteSparse convention: duplicate coordinates are assembled
            // by summation (both in the stated and the mirrored triangle).
            // The consumer sums; explicit zeros carry no information.
            if v != 0.0 {
                entries.push((i - 1, j - 1, v));
                if symmetric && i != j {
                    entries.push((j - 1, i - 1, v));
                }
            }
            seen += 1;
        }
        if seen != nnz {
            return Err(ferr(format!("expected {nnz} entries, found {seen}")));
        }
        Ok(MtxData {
            rows,
            cols,
            entries,
        })
    } else {
        let (&rows, &cols) = match dims.as_slice() {
            [r, c] => (r, c),
            _ => {
                return Err(ferr(format!(
                    "line {size_lineno}: array size line must be `rows cols`"
                )))
            }
        };
        let mut values = Vec::with_capacity(rows * cols);
        for (idx, line) in lines {
            let lineno = idx + 1;
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            for tok in t.split_whitespace() {
                values.push(
                    tok.parse::<f64>()
                        .map_err(|e| ferr(format!("line {lineno}: bad value: {e}")))?,
                );
            }
        }
        if values.len() != rows * cols {
            return Err(ferr(format!(
                "expected {} values, found {}",
                rows * cols,
                values.len()
            )));
        }
        // Array format is column-major; keep only nonzeros.
        let mut entries = Vec::new();
        for j in 0..cols {
            for i in 0..rows {
                let v = values[j * rows + i];
                if v != 0.0 {
                    entries.push((i, j, v));
                }
            }
        }
        Ok(MtxData {
            rows,
            cols,
            entries,
        })
    }
}

/// Write a dense matrix as `coordinate real general` (zeros omitted).
pub fn write_mtx(path: &Path, m: &Matrix) -> Result<(), MarketError> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(out, "% generated by MELISO+ (synthetic stand-in)")?;
    let nnz = m.data().iter().filter(|v| **v != 0.0).count();
    writeln!(out, "{} {} {}", m.nrows(), m.ncols(), nnz)?;
    for i in 0..m.nrows() {
        for j in 0..m.ncols() {
            let v = m.get(i, j);
            if v != 0.0 {
                writeln!(out, "{} {} {:e}", i + 1, j + 1, v)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrices::sparse::CsrSource;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("meliso_mtx_{name}_{}", std::process::id()));
        p
    }

    /// Test helper: dense view through the CSR path (the supported route).
    fn read_dense(p: &Path) -> Result<Matrix, MarketError> {
        Ok(CsrSource::from_mtx(p)?.to_dense())
    }

    #[test]
    fn roundtrip_coordinate() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 0.0, -2.5, 0.0, 3.25, 0.0]);
        let p = tmpfile("rt");
        write_mtx(&p, &m).unwrap();
        let back = read_dense(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back, m);
    }

    #[test]
    fn reads_symmetric() {
        let p = tmpfile("sym");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 4.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_dense(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), -1.0);
        assert_eq!(m.get(0, 0), 4.0);
    }

    #[test]
    fn reads_array_format() {
        let p = tmpfile("arr");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix array real general\n2 2\n1.0\n2.0\n3.0\n4.0\n",
        )
        .unwrap();
        let m = read_dense(&p).unwrap();
        std::fs::remove_file(&p).ok();
        // column-major: [1 3; 2 4]
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn triplet_stream_is_o_nnz_not_dense() {
        // A 10000x10000 operand with 2 stored entries: the triplet reader
        // returns 2 entries (the dense path would allocate 800 MB).
        let p = tmpfile("huge");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n10000 10000 2\n1 1 1.0\n10000 10000 2.0\n",
        )
        .unwrap();
        let data = read_mtx_triplets(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!((data.rows, data.cols), (10000, 10000));
        assert_eq!(data.entries, vec![(0, 0, 1.0), (9999, 9999, 2.0)]);
    }

    #[test]
    fn rejects_bad_header() {
        let p = tmpfile("bad");
        std::fs::write(&p, "not a matrix\n").unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(matches!(e, MarketError::Format(_)));
    }

    #[test]
    fn duplicate_entries_are_summed() {
        // SuiteSparse assembly convention: duplicates accumulate.
        let p = tmpfile("dup");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.5\n1 1 2.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_dense(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 0), 3.5);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn symmetric_diagonal_is_not_double_counted() {
        let p = tmpfile("symdiag");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 3\n1 1 4.0\n2 2 5.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_dense(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 0), 4.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn out_of_range_index_reports_line_number() {
        let p = tmpfile("oob");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n3 1 2.0\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("line 4"), "{msg}");
        assert!(msg.contains("out of range"), "{msg}");
        assert!(msg.contains("1-based"), "{msg}");
    }

    #[test]
    fn zero_index_reports_line_number() {
        let p = tmpfile("zero");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("line 3"), "{e}");
    }

    #[test]
    fn pattern_field_is_rejected_explicitly() {
        let p = tmpfile("pat");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 1\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("pattern"), "{msg}");
        assert!(msg.contains("line 1"), "{msg}");
    }

    #[test]
    fn complex_field_is_rejected_explicitly() {
        let p = tmpfile("cplx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate complex general\n2 2 1\n1 1 1.0 0.0\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("complex"), "{msg}");
        assert!(msg.contains("line 1"), "{msg}");
    }

    #[test]
    fn missing_value_and_trailing_tokens_are_errors() {
        let p = tmpfile("mval");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("line 3"), "{e}");
        assert!(e.to_string().contains("missing value"), "{e}");

        let p = tmpfile("trail");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0 9.9\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("trailing tokens"), "{e}");
    }

    #[test]
    fn rejects_wrong_nnz() {
        let p = tmpfile("nnz");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n",
        )
        .unwrap();
        let e = read_mtx_triplets(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(matches!(e, MarketError::Format(_)));
    }
}
