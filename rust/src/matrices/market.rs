//! MatrixMarket (.mtx) reader/writer.
//!
//! If a user drops the *real* SuiteSparse files into `data/`, the CLI loads
//! them instead of the synthetic stand-ins; the writer lets us cache
//! generated operands for inspection.  Supports the `matrix coordinate
//! real {general|symmetric}` and `matrix array real general` flavors.
//!
//! The coordinate reader follows the SuiteSparse conventions strictly:
//! 1-based indices are validated against the header dimensions, duplicate
//! entries are **summed** (assembled, as SuiteSparse defines them), and
//! every malformed entry is a [`MarketError::Format`] carrying its line
//! number.  `pattern` and `complex` fields are rejected up front with an
//! explicit message instead of being misparsed as real data.

use crate::linalg::Matrix;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

#[derive(Debug)]
pub enum MarketError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for MarketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MarketError::Io(e) => write!(f, "io error: {e}"),
            MarketError::Format(m) => write!(f, "matrixmarket format error: {m}"),
        }
    }
}

impl std::error::Error for MarketError {}

impl From<std::io::Error> for MarketError {
    fn from(e: std::io::Error) -> Self {
        MarketError::Io(e)
    }
}

fn ferr(msg: impl Into<String>) -> MarketError {
    MarketError::Format(msg.into())
}

/// Read a `.mtx` file into a dense [`Matrix`].
pub fn read_mtx(path: &Path) -> Result<Matrix, MarketError> {
    let file = std::fs::File::open(path)?;
    let mut lines = BufReader::new(file).lines().enumerate();

    let header = lines
        .next()
        .ok_or_else(|| ferr("empty file"))?
        .1?
        .to_lowercase();
    if !header.starts_with("%%matrixmarket") {
        return Err(ferr("missing %%MatrixMarket header"));
    }
    let tokens: Vec<&str> = header.split_whitespace().collect();
    let coordinate = match tokens.get(2) {
        Some(&"coordinate") => true,
        Some(&"array") => false,
        other => return Err(ferr(format!("unsupported format {other:?}"))),
    };
    match tokens.get(3) {
        Some(&"real") | Some(&"integer") => {}
        Some(&"pattern") => {
            return Err(ferr(
                "line 1: `pattern` fields are not supported (no values to program onto \
                 conductances); convert to real first",
            ))
        }
        Some(&"complex") => {
            return Err(ferr(
                "line 1: `complex` fields are not supported (crossbar operands are real); \
                 take the real part or the modulus first",
            ))
        }
        other => return Err(ferr(format!("line 1: unsupported field {other:?}"))),
    }
    let symmetric = match tokens.get(4) {
        Some(&"general") | None => false,
        Some(&"symmetric") => true,
        other => return Err(ferr(format!("unsupported symmetry {other:?}"))),
    };

    // Skip comments, read the size line.
    let mut size_line = None;
    for (idx, line) in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some((idx + 1, t.to_string()));
        break;
    }
    let (size_lineno, size_line) = size_line.ok_or_else(|| ferr("missing size line"))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|t| {
            t.parse::<usize>()
                .map_err(|e| ferr(format!("line {size_lineno}: bad size: {e}")))
        })
        .collect::<Result<_, _>>()?;

    if coordinate {
        let (&rows, &cols, &nnz) = match dims.as_slice() {
            [r, c, n] => (r, c, n),
            _ => {
                return Err(ferr(format!(
                    "line {size_lineno}: coordinate size line must be `rows cols nnz`"
                )))
            }
        };
        let mut m = Matrix::zeros(rows, cols);
        let mut seen = 0usize;
        for (idx, line) in lines {
            let lineno = idx + 1;
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            let mut it = t.split_whitespace();
            let i: usize = it
                .next()
                .ok_or_else(|| ferr(format!("line {lineno}: truncated entry")))?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad row index: {e}")))?;
            let j: usize = it
                .next()
                .ok_or_else(|| ferr(format!("line {lineno}: truncated entry")))?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad col index: {e}")))?;
            let v: f64 = it
                .next()
                .ok_or_else(|| {
                    ferr(format!(
                        "line {lineno}: missing value (pattern entries are not supported)"
                    ))
                })?
                .parse()
                .map_err(|e| ferr(format!("line {lineno}: bad value: {e}")))?;
            if it.next().is_some() {
                return Err(ferr(format!(
                    "line {lineno}: trailing tokens after `row col value`"
                )));
            }
            if i == 0 || j == 0 || i > rows || j > cols {
                return Err(ferr(format!(
                    "line {lineno}: index ({i},{j}) out of range for a {rows}x{cols} \
                     operand (indices are 1-based)"
                )));
            }
            // SuiteSparse convention: duplicate coordinates are assembled
            // by summation (both in the stated and the mirrored triangle).
            m.set(i - 1, j - 1, m.get(i - 1, j - 1) + v);
            if symmetric && i != j {
                m.set(j - 1, i - 1, m.get(j - 1, i - 1) + v);
            }
            seen += 1;
        }
        if seen != nnz {
            return Err(ferr(format!("expected {nnz} entries, found {seen}")));
        }
        Ok(m)
    } else {
        let (&rows, &cols) = match dims.as_slice() {
            [r, c] => (r, c),
            _ => {
                return Err(ferr(format!(
                    "line {size_lineno}: array size line must be `rows cols`"
                )))
            }
        };
        let mut values = Vec::with_capacity(rows * cols);
        for (idx, line) in lines {
            let lineno = idx + 1;
            let line = line?;
            let t = line.trim();
            if t.is_empty() || t.starts_with('%') {
                continue;
            }
            for tok in t.split_whitespace() {
                values.push(
                    tok.parse::<f64>()
                        .map_err(|e| ferr(format!("line {lineno}: bad value: {e}")))?,
                );
            }
        }
        if values.len() != rows * cols {
            return Err(ferr(format!(
                "expected {} values, found {}",
                rows * cols,
                values.len()
            )));
        }
        // Array format is column-major.
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, values[j * rows + i]);
            }
        }
        Ok(m)
    }
}

/// Write a dense matrix as `coordinate real general` (zeros omitted).
pub fn write_mtx(path: &Path, m: &Matrix) -> Result<(), MarketError> {
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(out, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(out, "% generated by MELISO+ (synthetic stand-in)")?;
    let nnz = m.data().iter().filter(|v| **v != 0.0).count();
    writeln!(out, "{} {} {}", m.nrows(), m.ncols(), nnz)?;
    for i in 0..m.nrows() {
        for j in 0..m.ncols() {
            let v = m.get(i, j);
            if v != 0.0 {
                writeln!(out, "{} {} {:e}", i + 1, j + 1, v)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("meliso_mtx_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_coordinate() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 0.0, -2.5, 0.0, 3.25, 0.0]);
        let p = tmpfile("rt");
        write_mtx(&p, &m).unwrap();
        let back = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back, m);
    }

    #[test]
    fn reads_symmetric() {
        let p = tmpfile("sym");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 4.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), -1.0);
        assert_eq!(m.get(0, 0), 4.0);
    }

    #[test]
    fn reads_array_format() {
        let p = tmpfile("arr");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix array real general\n2 2\n1.0\n2.0\n3.0\n4.0\n",
        )
        .unwrap();
        let m = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        // column-major: [1 3; 2 4]
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn rejects_bad_header() {
        let p = tmpfile("bad");
        std::fs::write(&p, "not a matrix\n").unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(matches!(e, MarketError::Format(_)));
    }

    #[test]
    fn duplicate_entries_are_summed() {
        // SuiteSparse assembly convention: duplicates accumulate.
        let p = tmpfile("dup");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.5\n1 1 2.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 0), 3.5);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn symmetric_diagonal_is_not_double_counted() {
        let p = tmpfile("symdiag");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n2 2 3\n1 1 4.0\n2 2 5.0\n2 1 -1.0\n",
        )
        .unwrap();
        let m = read_mtx(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m.get(0, 0), 4.0);
        assert_eq!(m.get(1, 1), 5.0);
        assert_eq!(m.get(0, 1), -1.0);
        assert_eq!(m.get(1, 0), -1.0);
    }

    #[test]
    fn out_of_range_index_reports_line_number() {
        let p = tmpfile("oob");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n3 1 2.0\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("line 4"), "{msg}");
        assert!(msg.contains("out of range"), "{msg}");
        assert!(msg.contains("1-based"), "{msg}");
    }

    #[test]
    fn zero_index_reports_line_number() {
        let p = tmpfile("zero");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("line 3"), "{e}");
    }

    #[test]
    fn pattern_field_is_rejected_explicitly() {
        let p = tmpfile("pat");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 1\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("pattern"), "{msg}");
        assert!(msg.contains("line 1"), "{msg}");
    }

    #[test]
    fn complex_field_is_rejected_explicitly() {
        let p = tmpfile("cplx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate complex general\n2 2 1\n1 1 1.0 0.0\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        let msg = e.to_string();
        assert!(msg.contains("complex"), "{msg}");
        assert!(msg.contains("line 1"), "{msg}");
    }

    #[test]
    fn missing_value_and_trailing_tokens_are_errors() {
        let p = tmpfile("mval");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("line 3"), "{e}");
        assert!(e.to_string().contains("missing value"), "{e}");

        let p = tmpfile("trail");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 1.0 9.9\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(e.to_string().contains("trailing tokens"), "{e}");
    }

    #[test]
    fn rejects_wrong_nnz() {
        let p = tmpfile("nnz");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n",
        )
        .unwrap();
        let e = read_mtx(&p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(matches!(e, MarketError::Format(_)));
    }
}
