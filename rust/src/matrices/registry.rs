//! Named registry of the paper's benchmark operands (Table 2 stand-ins).
//!
//! | name       | dim     | κ target   | ‖A‖₂ target | representation |
//! |------------|---------|------------|-------------|----------------|
//! | bcsstk02   | 66      | 4.325e3    | 1.8226e4    | dense SPD      |
//! | iperturb66 | 66      | 1.2342     | ≈1.1        | dense          |
//! | wang2      | 2,903   | 2.3055e4   | 4.1381      | dense SPD      |
//! | add32      | 4,960   | 1.3668e2   | 5.7493e-2   | banded (sparse)|
//! | c-38       | 8,127   | 1.5307e4   | 6.0835e2    | banded         |
//! | dubcova1   | 16,129  | 9.9712     | 4.7963      | banded         |
//! | helm3d01   | 32,226  | 2.4519e5   | 5.0522e-1   | banded         |
//! | dubcova2   | 65,025  | ~10 (n/a)  | ~4.8 (n/a)  | banded         |
//!
//! dubcova2's κ/‖A‖₂ are not published (Table 2 marks them `*`); we mirror
//! dubcova1, its refinement-hierarchy sibling.
//!
//! ## Irregular sparse testbed (CSR)
//!
//! Four procedural [`CsrSource`](super::sparse::CsrSource) operands
//! exercise planning and placement on *non-banded* structure.  All share
//! `d_max = 4`, `κ_target = 100`, `off_amp = 0.2`, so the condition
//! number lands in `[100, 150]` and ‖A‖₂ ≤ 4.8 by Gershgorin (see
//! [`generators::sparse_spd_from_pattern`]); all are SPD, so every
//! solver method applies:
//!
//! | name        | dim  | pattern                          | nnz (target)     |
//! |-------------|------|----------------------------------|------------------|
//! | arrow1k     | 1000 | arrowhead + superdiagonal        | 5n−6 ≈ 5.0k      |
//! | powlaw1k    | 1000 | hub-dominated power-law (3 hubs) | ≤ n(1+2·3) ≈ 7k  |
//! | blockdiag1k | 1000 | dense diagonal blocks, 8–64 wide | pattern-seeded   |
//! | sprand1k    | 1000 | uniform, 4 draws/row             | ≈ n(1+2·4) ≈ 9k  |
//!
//! ## File-backed operands
//!
//! `build("mtx:<path>")` — or any name ending in `.mtx` — loads a
//! Matrix-Market file as a [`CsrSource`](super::sparse::CsrSource)
//! (O(nnz) memory), so real SuiteSparse downloads run through exactly
//! the same planning/serving path as the synthetic testbed.

use super::generators;
use super::sparse::CsrSource;
use super::{BandedSource, DenseSource, MatrixSource};
use std::path::Path;
use std::sync::Arc;

/// Descriptor for a registered operand.
#[derive(Clone, Debug)]
pub struct MatrixInfo {
    pub name: &'static str,
    pub dim: usize,
    pub kappa: f64,
    pub norm2: f64,
    /// Section of the paper that uses it.
    pub used_in: &'static str,
}

/// All registered operands (paper Table 2 + Iperturb).
pub const CATALOG: &[MatrixInfo] = &[
    MatrixInfo {
        name: "bcsstk02",
        dim: 66,
        kappa: 4324.971,
        norm2: 1.822575e4,
        used_in: "2.2 (M1, Table 1, Fig S1/S2)",
    },
    MatrixInfo {
        name: "iperturb66",
        dim: 66,
        kappa: 1.2342,
        norm2: 1.105,
        used_in: "2.2 (M2, Table 1, Fig 2/3)",
    },
    MatrixInfo {
        name: "wang2",
        dim: 2903,
        kappa: 2.305543e4,
        norm2: 4.138078,
        used_in: "2.3.2 (Fig 5)",
    },
    MatrixInfo {
        name: "add32",
        dim: 4960,
        kappa: 1.366769e2,
        norm2: 5.749318e-2,
        used_in: "2.3.1 + 2.3.2 (Fig 4/5)",
    },
    MatrixInfo {
        name: "c-38",
        dim: 8127,
        kappa: 1.530683e4,
        norm2: 6.083484e2,
        used_in: "2.3.2 (Fig 5)",
    },
    MatrixInfo {
        name: "dubcova1",
        dim: 16129,
        kappa: 9.971199,
        norm2: 4.796329,
        used_in: "2.3.2 (Fig 5)",
    },
    MatrixInfo {
        name: "helm3d01",
        dim: 32226,
        kappa: 2.451897e5,
        norm2: 5.052177e-1,
        used_in: "2.3.2 (Fig 5)",
    },
    MatrixInfo {
        name: "dubcova2",
        dim: 65025,
        kappa: 9.971199,
        norm2: 4.796329,
        used_in: "2.3.2 (Fig 5)",
    },
    // Iterative-solver testbed (not from the paper): exact-spectrum SPD
    // pairs for CG and nonsymmetric pairs for GMRES, one well- and one
    // ill-conditioned each.  κ/‖A‖₂ are generator targets (exact for the
    // SPD pair, approximate for the nonsymmetric pair's κ).
    MatrixInfo {
        name: "spd64",
        dim: 64,
        kappa: 20.0,
        norm2: 4.0,
        used_in: "iterative solvers (CG testbed)",
    },
    MatrixInfo {
        name: "spdill64",
        dim: 64,
        kappa: 2.0e3,
        norm2: 4.0,
        used_in: "iterative solvers (ill-conditioned CG)",
    },
    MatrixInfo {
        name: "nonsym64",
        dim: 64,
        kappa: 20.0,
        norm2: 4.0,
        used_in: "iterative solvers (GMRES testbed)",
    },
    MatrixInfo {
        name: "nonsymill64",
        dim: 64,
        kappa: 2.0e3,
        norm2: 4.0,
        used_in: "iterative solvers (ill-conditioned GMRES)",
    },
    // Execution-plane scale testbed (not from the paper): procedural
    // banded operands so the at-scale path is one CLI command away.
    // `banded8k` is the CI smoke size; `banded65k` is the 65,536²
    // headline operand — both stream tile-by-tile and are never
    // materialized densely.
    MatrixInfo {
        name: "banded8k",
        dim: 8192,
        kappa: 1.0e2,
        norm2: 4.0,
        used_in: "plane scale testbed (CI smoke, benches/plane_scaling)",
    },
    MatrixInfo {
        name: "banded65k",
        dim: 65_536,
        kappa: 1.0e2,
        norm2: 4.0,
        used_in: "plane scale testbed (65,536² headline solve)",
    },
    // Irregular sparse testbed (not from the paper): CSR operands with
    // non-banded patterns, for sparsity-aware planning/placement.  κ in
    // [100, 150] and ‖A‖₂ ≤ 4.8 by construction (Gershgorin bounds of
    // `sparse_spd_from_pattern`); all SPD.
    MatrixInfo {
        name: "arrow1k",
        dim: 1000,
        kappa: 1.0e2,
        norm2: 4.8,
        used_in: "irregular sparse testbed (arrowhead, nnz=5n-6)",
    },
    MatrixInfo {
        name: "powlaw1k",
        dim: 1000,
        kappa: 1.0e2,
        norm2: 4.8,
        used_in: "irregular sparse testbed (hub power-law, nnz<=7n)",
    },
    MatrixInfo {
        name: "blockdiag1k",
        dim: 1000,
        kappa: 1.0e2,
        norm2: 4.8,
        used_in: "irregular sparse testbed (block diagonal, blocks 8-64)",
    },
    MatrixInfo {
        name: "sprand1k",
        dim: 1000,
        kappa: 1.0e2,
        norm2: 4.8,
        used_in: "irregular sparse testbed (uniform random, nnz~9n)",
    },
];

pub fn info(name: &str) -> Option<&'static MatrixInfo> {
    CATALOG.iter().find(|m| m.name == name)
}

/// Load a Matrix-Market file as a CSR operand (the `mtx:<path>` /
/// `*.mtx` registry route).
fn build_mtx(path: &str) -> Result<Arc<dyn MatrixSource>, String> {
    CsrSource::from_mtx(Path::new(path))
        .map(|s| Arc::new(s) as Arc<dyn MatrixSource>)
        .map_err(|e| format!("cannot load matrix file {path:?}: {e}"))
}

/// Build a named operand.  Unknown names produce an error listing options.
///
/// Besides the synthetic catalog, `mtx:<path>` (or any name ending in
/// `.mtx`) loads a Matrix-Market file as a
/// [`CsrSource`](super::sparse::CsrSource) — this is how the CLI's
/// `--matrix path/to/operand.mtx` serves real sparse files.
pub fn build(name: &str) -> Result<Arc<dyn MatrixSource>, String> {
    if let Some(path) = name.strip_prefix("mtx:") {
        return build_mtx(path);
    }
    if name.ends_with(".mtx") {
        return build_mtx(name);
    }
    let seed_base = 0x4D454C49u64; // "MELI"
    let src: Arc<dyn MatrixSource> = match name {
        "bcsstk02" => Arc::new(DenseSource::new(generators::dense_spd_with_condition(
            66,
            1.822575e4,
            4324.971,
            8,
            seed_base ^ 1,
        ))),
        "iperturb66" | "iperturb" => Arc::new(DenseSource::new(generators::iperturb(
            66,
            1.2342,
            seed_base ^ 2,
        ))),
        "wang2" => Arc::new(DenseSource::new(generators::dense_spd_with_condition(
            2903,
            4.138078,
            2.305543e4,
            8,
            seed_base ^ 3,
        ))),
        // add32 is genuinely sparse: ~1.7% density -> band half-width 42.
        "add32" => Arc::new(BandedSource::new(
            4960,
            42,
            5.749318e-2,
            1.366769e2,
            0.18,
            seed_base ^ 4,
        )),
        "c-38" | "c38" => Arc::new(BandedSource::new(
            8127,
            64,
            6.083484e2,
            1.530683e4,
            0.22,
            seed_base ^ 5,
        )),
        "dubcova1" => Arc::new(BandedSource::new(
            16129,
            48,
            4.796329,
            9.971199,
            0.20,
            seed_base ^ 6,
        )),
        "helm3d01" => Arc::new(BandedSource::new(
            32226,
            80,
            5.052177e-1,
            2.451897e5,
            0.15,
            seed_base ^ 7,
        )),
        "dubcova2" => Arc::new(BandedSource::new(
            65025,
            48,
            4.796329,
            9.971199,
            0.20,
            seed_base ^ 8,
        )),
        "spd64" => Arc::new(DenseSource::new(generators::dense_spd_with_condition(
            64,
            4.0,
            20.0,
            8,
            seed_base ^ 9,
        ))),
        "spdill64" => Arc::new(DenseSource::new(generators::dense_spd_with_condition(
            64,
            4.0,
            2.0e3,
            8,
            seed_base ^ 10,
        ))),
        "nonsym64" => Arc::new(DenseSource::new(
            generators::dense_nonsymmetric_with_condition(64, 4.0, 20.0, 0.25, 8, seed_base ^ 11),
        )),
        "nonsymill64" => Arc::new(DenseSource::new(
            generators::dense_nonsymmetric_with_condition(64, 4.0, 2.0e3, 0.25, 8, seed_base ^ 12),
        )),
        "banded8k" => Arc::new(BandedSource::new(
            8192,
            48,
            4.0,
            1.0e2,
            0.2,
            seed_base ^ 13,
        )),
        "banded65k" => Arc::new(BandedSource::new(
            65_536,
            48,
            4.0,
            1.0e2,
            0.2,
            seed_base ^ 14,
        )),
        "arrow1k" => Arc::new(generators::arrowhead_csr(1000, 4.0, 1.0e2, 0.2, seed_base ^ 15)),
        "powlaw1k" => Arc::new(generators::power_law_csr(
            1000,
            3,
            4.0,
            1.0e2,
            0.2,
            seed_base ^ 16,
        )),
        "blockdiag1k" => Arc::new(generators::block_diag_csr(
            1000,
            64,
            4.0,
            1.0e2,
            0.2,
            seed_base ^ 17,
        )),
        "sprand1k" => Arc::new(generators::sprand_spd_csr(
            1000,
            4,
            4.0,
            1.0e2,
            0.2,
            seed_base ^ 18,
        )),
        other => {
            let names: Vec<&str> = CATALOG.iter().map(|m| m.name).collect();
            return Err(format!(
                "unknown matrix {other:?}; available: {}",
                names.join(", ")
            ));
        }
    };
    Ok(src)
}

/// The strong-scaling sweep order (Fig 5's x-axis).
pub const STRONG_SCALING_ORDER: &[&str] = &[
    "bcsstk02",
    "wang2",
    "add32",
    "c-38",
    "dubcova1",
    "helm3d01",
    "dubcova2",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_strong_scaling() {
        for name in STRONG_SCALING_ORDER {
            assert!(info(name).is_some(), "{name} missing from catalog");
        }
    }

    #[test]
    fn build_small_matrices() {
        for name in ["bcsstk02", "iperturb66"] {
            let m = build(name).unwrap();
            assert_eq!(m.nrows(), 66);
            assert_eq!(m.ncols(), 66);
        }
    }

    #[test]
    fn build_unknown_is_error() {
        let err = match build("nope") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("unknown matrix"));
        assert!(err.contains("bcsstk02"));
    }

    #[test]
    fn banded_dims_match_catalog() {
        let m = build("add32").unwrap();
        assert_eq!(m.nrows(), 4960);
        // Sparse: a far-off-diagonal block is zero.
        assert!(m.block_is_zero(0, 2000, 128, 128));
    }

    #[test]
    fn scale_testbed_operands_build_procedurally() {
        // Building is O(1) — these are procedural sources, never dense.
        for (name, dim) in [("banded8k", 8192usize), ("banded65k", 65_536)] {
            let m = build(name).unwrap();
            assert_eq!(m.nrows(), dim, "{name}");
            assert_eq!(m.ncols(), dim, "{name}");
            assert!(info(name).is_some(), "{name} missing from catalog");
            // Far off-diagonal blocks are certainly zero, and the
            // occupied column span is band-bounded.
            assert!(m.block_is_zero(0, dim / 2, 1024, 1024), "{name}");
            let (lo, hi) = m.occupied_cols(dim / 2, 1024);
            assert!(hi - lo <= 1024 + 2 * 48, "{name}: [{lo},{hi})");
        }
    }

    #[test]
    fn irregular_sparse_operands_build_and_plan_tightly() {
        use crate::virtualization::{ChunkPlan, SystemGeometry};
        for name in ["arrow1k", "powlaw1k", "blockdiag1k", "sprand1k"] {
            let m = build(name).unwrap();
            assert_eq!(m.nrows(), 1000, "{name}");
            assert_eq!(m.ncols(), 1000, "{name}");
            assert!(info(name).is_some(), "{name} missing from catalog");
        }
        // Planning visits strictly fewer chunks than the full grid for
        // the *structured* patterns — the whole point of serving
        // irregular sparsity via CSR.  (`sprand1k`'s uniform pattern is
        // dense at the chunk level by design, so it is excluded here.)
        for name in ["arrow1k", "powlaw1k", "blockdiag1k"] {
            let m = build(name).unwrap();
            let plan = ChunkPlan::new(SystemGeometry::new(4, 4, 16), 1000, 1000);
            let planned = plan.nonzero_chunks(m.as_ref()).count();
            assert!(
                planned < plan.total_chunks(),
                "{name}: planned {planned} of {}",
                plan.total_chunks()
            );
        }
    }

    #[test]
    fn mtx_route_builds_file_backed_operands() {
        let mut p = std::env::temp_dir();
        p.push(format!("meliso_registry_{}.mtx", std::process::id()));
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 4\n1 1 4.0\n2 2 4.0\n3 3 4.0\n2 1 -1.0\n",
        )
        .unwrap();
        let path = p.to_str().unwrap().to_string();
        // Both spellings resolve to the same CSR operand.
        for name in [format!("mtx:{path}"), path.clone()] {
            let m = build(&name).unwrap();
            assert_eq!((m.nrows(), m.ncols()), (3, 3), "{name}");
            assert!(!m.block_is_zero(0, 0, 2, 2), "{name}");
            assert!(m.block_is_zero(0, 2, 1, 1), "{name}");
        }
        std::fs::remove_file(&p).ok();
        let err = build("mtx:/nonexistent/file.mtx").unwrap_err();
        assert!(err.contains("cannot load"), "{err}");
    }

    #[test]
    fn bcsstk02_standin_matches_table2() {
        use crate::linalg::cond;
        let m = build("bcsstk02").unwrap();
        let dense = m.block(0, 0, 66, 66);
        let smax = cond::spectral_norm(&dense, 400, 1);
        assert!((smax - 1.822575e4).abs() / 1.822575e4 < 1e-2, "{smax}");
        let k = cond::condition_number(&dense, 400, 2).unwrap();
        assert!((k - 4324.971).abs() / 4324.971 < 0.05, "{k}");
    }

    #[test]
    fn solver_testbed_operands_build() {
        use crate::linalg::cond;
        for name in ["spd64", "spdill64", "nonsym64", "nonsymill64"] {
            let m = build(name).unwrap();
            assert_eq!(m.nrows(), 64, "{name}");
            assert_eq!(m.ncols(), 64, "{name}");
        }
        // The SPD pair has an exact generator spectrum.
        let spd = build("spd64").unwrap().block(0, 0, 64, 64);
        let k = cond::condition_number(&spd, 400, 4).unwrap();
        assert!((k - 20.0).abs() / 20.0 < 0.02, "{k}");
        // The nonsymmetric pair is genuinely nonsymmetric.
        let ns = build("nonsym64").unwrap().block(0, 0, 64, 64);
        let mut asym = 0.0f64;
        for i in 0..64 {
            for j in 0..64 {
                asym = asym.max((ns.get(i, j) - ns.get(j, i)).abs());
            }
        }
        assert!(asym > 1e-3, "{asym}");
    }

    #[test]
    fn iperturb_standin_matches_table1_condition() {
        use crate::linalg::cond;
        let m = build("iperturb66").unwrap();
        let dense = m.block(0, 0, 66, 66);
        let k = cond::condition_number(&dense, 400, 3).unwrap();
        assert!((k - 1.2342).abs() < 0.02, "{k}");
    }
}
