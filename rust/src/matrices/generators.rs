//! Synthetic matrix generators: SuiteSparse stand-ins (DESIGN.md §3,
//! substitution 1).
//!
//! Dense generators give *exact* spectra: `A = Q D Qᵀ` where `D` carries a
//! geometric singular-value profile from `σ_max` down to `σ_max/κ` and `Q`
//! is a product of Householder reflections (exactly orthogonal for any
//! number of reflections).  Procedural banded generators (for ≥8127²) put
//! the same geometric profile on the diagonal with decaying random
//! off-diagonals, which tracks the target condition number to within a
//! small factor — validated by `linalg::cond` in the tests.

use crate::linalg::{Matrix, Vector};
use crate::util::rng::Rng;

/// Dense symmetric matrix with exact spectrum: geometric eigenvalues from
/// `sigma_max` down to `sigma_max / kappa`, conjugated by `reflections`
/// random Householder reflections.
pub fn dense_spd_with_condition(
    n: usize,
    sigma_max: f64,
    kappa: f64,
    reflections: usize,
    seed: u64,
) -> Matrix {
    assert!(n > 1 && sigma_max > 0.0 && kappa >= 1.0);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        let t = i as f64 / (n - 1) as f64;
        a.set(i, i, sigma_max * kappa.powf(-t));
    }
    let mut rng = Rng::new(seed);
    for _ in 0..reflections {
        let u = random_unit(n, &mut rng);
        apply_householder_two_sided(&mut a, &u);
    }
    a
}

/// The paper's `Iperturb`: a slightly perturbed identity.  The symmetric
/// perturbation is scaled so κ(A) ≈ `kappa_target` (for the paper's value
/// 1.2342, the spectral half-width is ≈ 0.105).
pub fn iperturb(n: usize, kappa_target: f64, seed: u64) -> Matrix {
    assert!(kappa_target >= 1.0);
    // Eigenvalues in [1-e, 1+e]  =>  kappa = (1+e)/(1-e)  =>
    // e = (kappa-1)/(kappa+1).
    let e = (kappa_target - 1.0) / (kappa_target + 1.0);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        let t = i as f64 / (n - 1) as f64;
        a.set(i, i, (1.0 - e) + 2.0 * e * t);
    }
    let mut rng = Rng::new(seed);
    for _ in 0..4 {
        let u = random_unit(n, &mut rng);
        apply_householder_two_sided(&mut a, &u);
    }
    a
}

/// Dense nonsymmetric matrix: the exact-spectrum SPD core of
/// [`dense_spd_with_condition`] plus a scaled random skew-symmetric
/// perturbation.  The symmetric part *is* the SPD core (the skew addition
/// cancels under transpose-averaging), so the field of values stays in
/// the right half-plane and GMRES remains well-posed; `skew` sets the
/// spectral norm of the skew part relative to `sigma_max` (≈, via the
/// semicircle radius of a random skew matrix), so the condition number
/// tracks `kappa` up to a small factor.
pub fn dense_nonsymmetric_with_condition(
    n: usize,
    sigma_max: f64,
    kappa: f64,
    skew: f64,
    reflections: usize,
    seed: u64,
) -> Matrix {
    assert!(skew >= 0.0);
    let mut a = dense_spd_with_condition(n, sigma_max, kappa, reflections, seed);
    let g = Matrix::standard_normal(n, n, seed ^ 0x5EED_CAFE);
    // ‖G − Gᵀ‖₂ ≈ 2·√(2n) for i.i.d. N(0,1) entries.
    let scale = skew * sigma_max / (2.0 * (2.0 * n as f64).sqrt());
    for i in 0..n {
        for j in 0..n {
            let k = g.get(i, j) - g.get(j, i);
            a.set(i, j, a.get(i, j) + scale * k);
        }
    }
    a
}

/// Random unit vector.
fn random_unit(n: usize, rng: &mut Rng) -> Vector {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let mut v = Vector::from_vec(v);
    let norm = v.norm_l2();
    for x in v.data_mut() {
        *x /= norm;
    }
    v
}

/// A <- H A H with H = I - 2 u uᵀ (exactly orthogonal similarity).
fn apply_householder_two_sided(a: &mut Matrix, u: &Vector) {
    let n = a.nrows();
    debug_assert_eq!(n, a.ncols());
    debug_assert_eq!(n, u.len());
    // Left: A <- A - 2 u (uᵀ A)
    let mut uta = vec![0.0; n];
    for i in 0..n {
        let ui = u.get(i);
        if ui == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (j, r) in row.iter().enumerate() {
            uta[j] += ui * r;
        }
    }
    for i in 0..n {
        let ui = 2.0 * u.get(i);
        let row = a.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= ui * uta[j];
        }
    }
    // Right: A <- A - 2 (A u) uᵀ
    let mut au = vec![0.0; n];
    for (i, slot) in au.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0.0;
        for (j, r) in row.iter().enumerate() {
            acc += r * u.get(j);
        }
        *slot = acc;
    }
    for i in 0..n {
        let s = 2.0 * au[i];
        let row = a.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= s * u.get(j);
        }
    }
}

/// Sparsify a dense matrix by zeroing entries below `threshold * max_abs`
/// (used to hit Table 2's `nzeros` fractions when needed).
pub fn sparsify(a: &mut Matrix, threshold: f64) {
    let cutoff = threshold * a.max_abs();
    for v in a.data_mut() {
        if v.abs() < cutoff {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cond;

    #[test]
    fn dense_spd_hits_spectrum() {
        let a = dense_spd_with_condition(48, 100.0, 1000.0, 6, 7);
        let smax = cond::spectral_norm(&a, 300, 1);
        assert!((smax - 100.0).abs() / 100.0 < 1e-3, "smax={smax}");
        let k = cond::condition_number(&a, 300, 2).unwrap();
        assert!((k - 1000.0).abs() / 1000.0 < 1e-2, "kappa={k}");
    }

    #[test]
    fn dense_spd_is_symmetric() {
        let a = dense_spd_with_condition(24, 5.0, 40.0, 5, 3);
        for i in 0..24 {
            for j in 0..24 {
                assert!((a.get(i, j) - a.get(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn iperturb_condition() {
        let a = iperturb(66, 1.2342, 11);
        let k = cond::condition_number(&a, 400, 5).unwrap();
        assert!((k - 1.2342).abs() < 0.01, "kappa={k}");
        // Near identity: diagonal close to 1, off-diagonal small.
        let mut off_max = 0.0f64;
        for i in 0..66 {
            assert!((a.get(i, i) - 1.0).abs() < 0.25);
            for j in 0..66 {
                if i != j {
                    off_max = off_max.max(a.get(i, j).abs());
                }
            }
        }
        assert!(off_max < 0.2, "off_max={off_max}");
    }

    #[test]
    fn nonsymmetric_has_spd_symmetric_part() {
        let n = 24;
        let spd = dense_spd_with_condition(n, 4.0, 30.0, 6, 17);
        let a = dense_nonsymmetric_with_condition(n, 4.0, 30.0, 0.25, 6, 17);
        // Genuinely nonsymmetric...
        let mut max_asym = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                max_asym = max_asym.max((a.get(i, j) - a.get(j, i)).abs());
            }
        }
        assert!(max_asym > 1e-3, "{max_asym}");
        // ...but the symmetric part is exactly the SPD core.
        for i in 0..n {
            for j in 0..n {
                let sym = 0.5 * (a.get(i, j) + a.get(j, i));
                assert!((sym - spd.get(i, j)).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn nonsymmetric_zero_skew_is_spd_core() {
        let a = dense_nonsymmetric_with_condition(12, 2.0, 8.0, 0.0, 4, 19);
        let spd = dense_spd_with_condition(12, 2.0, 8.0, 4, 19);
        assert_eq!(a.data(), spd.data());
    }

    #[test]
    fn householder_preserves_frobenius() {
        let mut a = dense_spd_with_condition(20, 3.0, 9.0, 0, 1);
        let before = a.fro_norm();
        let mut rng = Rng::new(2);
        let u = random_unit(20, &mut rng);
        apply_householder_two_sided(&mut a, &u);
        assert!((a.fro_norm() - before).abs() < 1e-9);
    }

    #[test]
    fn sparsify_zeroes_small_entries() {
        let mut a = Matrix::from_vec(2, 2, vec![1.0, 1e-4, -1e-4, -1.0]);
        sparsify(&mut a, 1e-2);
        assert_eq!(a.get(0, 1), 0.0);
        assert_eq!(a.get(1, 0), 0.0);
        assert_eq!(a.get(0, 0), 1.0);
    }

    #[test]
    fn generators_deterministic() {
        let a = dense_spd_with_condition(16, 2.0, 8.0, 4, 42);
        let b = dense_spd_with_condition(16, 2.0, 8.0, 4, 42);
        assert_eq!(a.data(), b.data());
    }
}
