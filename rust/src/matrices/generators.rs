//! Synthetic matrix generators: SuiteSparse stand-ins (DESIGN.md §3,
//! substitution 1).
//!
//! Dense generators give *exact* spectra: `A = Q D Qᵀ` where `D` carries a
//! geometric singular-value profile from `σ_max` down to `σ_max/κ` and `Q`
//! is a product of Householder reflections (exactly orthogonal for any
//! number of reflections).  Procedural banded generators (for ≥8127²) put
//! the same geometric profile on the diagonal with decaying random
//! off-diagonals, which tracks the target condition number to within a
//! small factor — validated by `linalg::cond` in the tests.

use super::sparse::CsrSource;
use crate::linalg::{Matrix, Vector};
use crate::util::rng::Rng;

/// Dense symmetric matrix with exact spectrum: geometric eigenvalues from
/// `sigma_max` down to `sigma_max / kappa`, conjugated by `reflections`
/// random Householder reflections.
pub fn dense_spd_with_condition(
    n: usize,
    sigma_max: f64,
    kappa: f64,
    reflections: usize,
    seed: u64,
) -> Matrix {
    assert!(n > 1 && sigma_max > 0.0 && kappa >= 1.0);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        let t = i as f64 / (n - 1) as f64;
        a.set(i, i, sigma_max * kappa.powf(-t));
    }
    let mut rng = Rng::new(seed);
    for _ in 0..reflections {
        let u = random_unit(n, &mut rng);
        apply_householder_two_sided(&mut a, &u);
    }
    a
}

/// The paper's `Iperturb`: a slightly perturbed identity.  The symmetric
/// perturbation is scaled so κ(A) ≈ `kappa_target` (for the paper's value
/// 1.2342, the spectral half-width is ≈ 0.105).
pub fn iperturb(n: usize, kappa_target: f64, seed: u64) -> Matrix {
    assert!(kappa_target >= 1.0);
    // Eigenvalues in [1-e, 1+e]  =>  kappa = (1+e)/(1-e)  =>
    // e = (kappa-1)/(kappa+1).
    let e = (kappa_target - 1.0) / (kappa_target + 1.0);
    let mut a = Matrix::zeros(n, n);
    for i in 0..n {
        let t = i as f64 / (n - 1) as f64;
        a.set(i, i, (1.0 - e) + 2.0 * e * t);
    }
    let mut rng = Rng::new(seed);
    for _ in 0..4 {
        let u = random_unit(n, &mut rng);
        apply_householder_two_sided(&mut a, &u);
    }
    a
}

/// Dense nonsymmetric matrix: the exact-spectrum SPD core of
/// [`dense_spd_with_condition`] plus a scaled random skew-symmetric
/// perturbation.  The symmetric part *is* the SPD core (the skew addition
/// cancels under transpose-averaging), so the field of values stays in
/// the right half-plane and GMRES remains well-posed; `skew` sets the
/// spectral norm of the skew part relative to `sigma_max` (≈, via the
/// semicircle radius of a random skew matrix), so the condition number
/// tracks `kappa` up to a small factor.
pub fn dense_nonsymmetric_with_condition(
    n: usize,
    sigma_max: f64,
    kappa: f64,
    skew: f64,
    reflections: usize,
    seed: u64,
) -> Matrix {
    assert!(skew >= 0.0);
    let mut a = dense_spd_with_condition(n, sigma_max, kappa, reflections, seed);
    let g = Matrix::standard_normal(n, n, seed ^ 0x5EED_CAFE);
    // ‖G − Gᵀ‖₂ ≈ 2·√(2n) for i.i.d. N(0,1) entries.
    let scale = skew * sigma_max / (2.0 * (2.0 * n as f64).sqrt());
    for i in 0..n {
        for j in 0..n {
            let k = g.get(i, j) - g.get(j, i);
            a.set(i, j, a.get(i, j) + scale * k);
        }
    }
    a
}

/// Random unit vector.
fn random_unit(n: usize, rng: &mut Rng) -> Vector {
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let mut v = Vector::from_vec(v);
    let norm = v.norm_l2();
    for x in v.data_mut() {
        *x /= norm;
    }
    v
}

/// A <- H A H with H = I - 2 u uᵀ (exactly orthogonal similarity).
fn apply_householder_two_sided(a: &mut Matrix, u: &Vector) {
    let n = a.nrows();
    debug_assert_eq!(n, a.ncols());
    debug_assert_eq!(n, u.len());
    // Left: A <- A - 2 u (uᵀ A)
    let mut uta = vec![0.0; n];
    for i in 0..n {
        let ui = u.get(i);
        if ui == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (j, r) in row.iter().enumerate() {
            uta[j] += ui * r;
        }
    }
    for i in 0..n {
        let ui = 2.0 * u.get(i);
        let row = a.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= ui * uta[j];
        }
    }
    // Right: A <- A - 2 (A u) uᵀ
    let mut au = vec![0.0; n];
    for (i, slot) in au.iter_mut().enumerate() {
        let row = a.row(i);
        let mut acc = 0.0;
        for (j, r) in row.iter().enumerate() {
            acc += r * u.get(j);
        }
        *slot = acc;
    }
    for i in 0..n {
        let s = 2.0 * au[i];
        let row = a.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= s * u.get(j);
        }
    }
}

/// Symmetric positive-definite CSR operand over an arbitrary
/// strict-upper-triangle `pattern`.
///
/// The diagonal carries the same geometric profile as
/// [`BandedSource`](super::BandedSource) — `d(i)` spans
/// `d_max .. d_max/kappa_target` — and each pattern entry gets the value
/// `√(d_i·d_j)·u_ij` (deterministic `u ∈ [-1, 1]`), rescaled per row so
/// the absolute off-diagonal row sums never exceed `off_amp·d(i)`.  That
/// makes the matrix strictly diagonally dominant with positive diagonal,
/// hence SPD, and pins the spectrum by Gershgorin to
/// `[d(i)·(1−off_amp), d(i)·(1+off_amp)]`:
///
/// * condition number within `(1+off_amp)/(1−off_amp)` of `kappa_target`
///   (for the default `off_amp = 0.2`: within 1.5×),
/// * spectral norm at most `d_max·(1+off_amp)`.
///
/// Duplicate pattern pairs are legal (their contributions sum; the row
/// budget counts every draw, so dominance still holds).
pub fn sparse_spd_from_pattern(
    n: usize,
    pattern: &[(usize, usize)],
    d_max: f64,
    kappa_target: f64,
    off_amp: f64,
    seed: u64,
) -> CsrSource {
    assert!(n > 1 && d_max > 0.0 && kappa_target >= 1.0);
    assert!((0.0..1.0).contains(&off_amp), "off_amp must be in [0, 1)");
    let diag = |i: usize| -> f64 {
        let t = i as f64 / (n - 1) as f64;
        d_max * kappa_target.powf(-t)
    };
    let mut rng = Rng::new(seed);
    // Raw magnitudes first; per-row totals set the rescaling budget.
    let mut raw: Vec<(usize, usize, f64)> = Vec::with_capacity(pattern.len());
    let mut row_sum = vec![0.0f64; n];
    for &(i, j) in pattern {
        assert!(i < j && j < n, "pattern must be strict upper triangle");
        let w = (diag(i) * diag(j)).sqrt() * rng.uniform_range(-1.0, 1.0);
        raw.push((i, j, w));
        row_sum[i] += w.abs();
        row_sum[j] += w.abs();
    }
    let budget: Vec<f64> = (0..n)
        .map(|i| {
            if row_sum[i] > 0.0 {
                (off_amp * diag(i) / row_sum[i]).min(1.0)
            } else {
                0.0
            }
        })
        .collect();
    let mut trip: Vec<(usize, usize, f64)> = Vec::with_capacity(2 * raw.len() + n);
    for &(i, j, w) in &raw {
        let v = w * budget[i].min(budget[j]);
        if v != 0.0 {
            trip.push((i, j, v));
            trip.push((j, i, v));
        }
    }
    for i in 0..n {
        trip.push((i, i, diag(i)));
    }
    CsrSource::from_triplets(n, n, &trip).expect("pattern indices validated above")
}

/// Arrowhead SPD operand: a full first row/column plus the superdiagonal.
///
/// The canonical "wide span, sparse interior" stress case for planning:
/// every block row's [`occupied_cols`](crate::matrices::MatrixSource::occupied_cols)
/// span reaches column 0, so chunk candidates are pruned by the *exact*
/// [`block_is_zero`](crate::matrices::MatrixSource::block_is_zero) rather
/// than the column bound.  nnz = 5n − 6 (≈ 0.5% dense at n = 1000);
/// condition/norm targets as in [`sparse_spd_from_pattern`].
pub fn arrowhead_csr(
    n: usize,
    d_max: f64,
    kappa_target: f64,
    off_amp: f64,
    seed: u64,
) -> CsrSource {
    assert!(n > 2);
    let mut pattern: Vec<(usize, usize)> = (1..n).map(|j| (0, j)).collect();
    pattern.extend((2..n).map(|j| (j - 1, j)));
    sparse_spd_from_pattern(n, &pattern, d_max, kappa_target, off_amp, seed)
}

/// Power-law (hub-dominated) SPD operand: every row couples to
/// `mean_degree` draws from a small set of `max(3, n/512)` seeded hub
/// columns, so column degrees are heavy-tailed — hubs collect ~`n`
/// couplings each while every other column has O(1) (scale-free-style
/// structure).
///
/// nnz ≤ n·(1 + 2·mean_degree) (duplicate draws assemble into one
/// entry), and the occupied chunks are *provably* confined to the
/// diagonal plus the hub block-rows/columns — at most
/// `(2·hubs + 1)·grid` of `grid²` for any tile size — so planning wins
/// are deterministic, not probabilistic.  Condition/norm targets as in
/// [`sparse_spd_from_pattern`].
pub fn power_law_csr(
    n: usize,
    mean_degree: usize,
    d_max: f64,
    kappa_target: f64,
    off_amp: f64,
    seed: u64,
) -> CsrSource {
    assert!(n > 2 && mean_degree > 0);
    let mut rng = Rng::new(seed ^ 0x50574C41);
    let hub_count = (n / 512).max(3);
    let hubs: Vec<usize> = (0..hub_count).map(|_| rng.below(n)).collect();
    let mut pattern = Vec::with_capacity(n * mean_degree);
    for i in 0..n {
        for _ in 0..mean_degree {
            let h = hubs[rng.below(hubs.len())];
            if h != i {
                pattern.push((i.min(h), i.max(h)));
            }
        }
    }
    sparse_spd_from_pattern(n, &pattern, d_max, kappa_target, off_amp, seed)
}

/// Block-diagonal SPD operand: dense blocks of seeded sizes in
/// `[8, max_block]` along the diagonal, nothing in between — the
/// load-imbalance stress case (whole chunk columns between blocks are
/// empty).  Condition/norm targets as in [`sparse_spd_from_pattern`].
pub fn block_diag_csr(
    n: usize,
    max_block: usize,
    d_max: f64,
    kappa_target: f64,
    off_amp: f64,
    seed: u64,
) -> CsrSource {
    assert!(n > 2 && max_block >= 8);
    let mut rng = Rng::new(seed ^ 0x424C4B44);
    let mut pattern = Vec::new();
    let mut i0 = 0usize;
    while i0 < n {
        let bs = (8 + rng.below(max_block - 7)).min(n - i0);
        for i in i0..i0 + bs {
            for j in (i + 1)..i0 + bs {
                pattern.push((i, j));
            }
        }
        i0 += bs;
    }
    sparse_spd_from_pattern(n, &pattern, d_max, kappa_target, off_amp, seed)
}

/// Uniform (Erdős–Rényi-style) sparse SPD operand: each row draws
/// `degree` partner columns uniformly.  Expected nnz ≈ n·(1 + 2·degree);
/// condition/norm targets as in [`sparse_spd_from_pattern`].
pub fn sprand_spd_csr(
    n: usize,
    degree: usize,
    d_max: f64,
    kappa_target: f64,
    off_amp: f64,
    seed: u64,
) -> CsrSource {
    assert!(n > 2 && degree > 0);
    let mut rng = Rng::new(seed ^ 0x53505244);
    let mut pattern = Vec::with_capacity(n * degree);
    for i in 0..n {
        for _ in 0..degree {
            let j = rng.below(n);
            if i != j {
                pattern.push((i.min(j), i.max(j)));
            }
        }
    }
    sparse_spd_from_pattern(n, &pattern, d_max, kappa_target, off_amp, seed)
}

/// Sparsify a dense matrix by zeroing entries below `threshold * max_abs`
/// (used to hit Table 2's `nzeros` fractions when needed).
pub fn sparsify(a: &mut Matrix, threshold: f64) {
    let cutoff = threshold * a.max_abs();
    for v in a.data_mut() {
        if v.abs() < cutoff {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cond;

    #[test]
    fn dense_spd_hits_spectrum() {
        let a = dense_spd_with_condition(48, 100.0, 1000.0, 6, 7);
        let smax = cond::spectral_norm(&a, 300, 1);
        assert!((smax - 100.0).abs() / 100.0 < 1e-3, "smax={smax}");
        let k = cond::condition_number(&a, 300, 2).unwrap();
        assert!((k - 1000.0).abs() / 1000.0 < 1e-2, "kappa={k}");
    }

    #[test]
    fn dense_spd_is_symmetric() {
        let a = dense_spd_with_condition(24, 5.0, 40.0, 5, 3);
        for i in 0..24 {
            for j in 0..24 {
                assert!((a.get(i, j) - a.get(j, i)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn iperturb_condition() {
        let a = iperturb(66, 1.2342, 11);
        let k = cond::condition_number(&a, 400, 5).unwrap();
        assert!((k - 1.2342).abs() < 0.01, "kappa={k}");
        // Near identity: diagonal close to 1, off-diagonal small.
        let mut off_max = 0.0f64;
        for i in 0..66 {
            assert!((a.get(i, i) - 1.0).abs() < 0.25);
            for j in 0..66 {
                if i != j {
                    off_max = off_max.max(a.get(i, j).abs());
                }
            }
        }
        assert!(off_max < 0.2, "off_max={off_max}");
    }

    #[test]
    fn nonsymmetric_has_spd_symmetric_part() {
        let n = 24;
        let spd = dense_spd_with_condition(n, 4.0, 30.0, 6, 17);
        let a = dense_nonsymmetric_with_condition(n, 4.0, 30.0, 0.25, 6, 17);
        // Genuinely nonsymmetric...
        let mut max_asym = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                max_asym = max_asym.max((a.get(i, j) - a.get(j, i)).abs());
            }
        }
        assert!(max_asym > 1e-3, "{max_asym}");
        // ...but the symmetric part is exactly the SPD core.
        for i in 0..n {
            for j in 0..n {
                let sym = 0.5 * (a.get(i, j) + a.get(j, i));
                assert!((sym - spd.get(i, j)).abs() < 1e-10, "({i},{j})");
            }
        }
    }

    #[test]
    fn nonsymmetric_zero_skew_is_spd_core() {
        let a = dense_nonsymmetric_with_condition(12, 2.0, 8.0, 0.0, 4, 19);
        let spd = dense_spd_with_condition(12, 2.0, 8.0, 4, 19);
        assert_eq!(a.data(), spd.data());
    }

    #[test]
    fn householder_preserves_frobenius() {
        let mut a = dense_spd_with_condition(20, 3.0, 9.0, 0, 1);
        let before = a.fro_norm();
        let mut rng = Rng::new(2);
        let u = random_unit(20, &mut rng);
        apply_householder_two_sided(&mut a, &u);
        assert!((a.fro_norm() - before).abs() < 1e-9);
    }

    #[test]
    fn sparsify_zeroes_small_entries() {
        let mut a = Matrix::from_vec(2, 2, vec![1.0, 1e-4, -1e-4, -1.0]);
        sparsify(&mut a, 1e-2);
        assert_eq!(a.get(0, 1), 0.0);
        assert_eq!(a.get(1, 0), 0.0);
        assert_eq!(a.get(0, 0), 1.0);
    }

    #[test]
    fn generators_deterministic() {
        let a = dense_spd_with_condition(16, 2.0, 8.0, 4, 42);
        let b = dense_spd_with_condition(16, 2.0, 8.0, 4, 42);
        assert_eq!(a.data(), b.data());
    }

    /// Strict diagonal dominance + symmetry (the SPD guarantee) for every
    /// sparse pattern generator.
    fn assert_sdd_symmetric(a: &CsrSource, off_amp: f64) {
        use crate::matrices::MatrixSource;
        let n = a.nrows();
        for i in 0..n {
            let (cols, vals) = a.row(i);
            let mut off = 0.0;
            let mut d = 0.0;
            for (&j, &v) in cols.iter().zip(vals) {
                if j == i {
                    d = v;
                } else {
                    off += v.abs();
                    assert_eq!(v, a.get(j, i), "asymmetric at ({i},{j})");
                }
            }
            assert!(d > 0.0, "row {i} missing positive diagonal");
            assert!(
                off <= off_amp * d * (1.0 + 1e-12),
                "row {i}: off sum {off} exceeds {off_amp}*{d}"
            );
        }
    }

    #[test]
    fn sparse_generators_are_spd_and_deterministic() {
        let gens: Vec<(&str, CsrSource, CsrSource)> = vec![
            (
                "arrowhead",
                arrowhead_csr(200, 4.0, 100.0, 0.2, 9),
                arrowhead_csr(200, 4.0, 100.0, 0.2, 9),
            ),
            (
                "power-law",
                power_law_csr(200, 3, 4.0, 100.0, 0.2, 9),
                power_law_csr(200, 3, 4.0, 100.0, 0.2, 9),
            ),
            (
                "block-diag",
                block_diag_csr(200, 48, 4.0, 100.0, 0.2, 9),
                block_diag_csr(200, 48, 4.0, 100.0, 0.2, 9),
            ),
            (
                "sprand",
                sprand_spd_csr(200, 4, 4.0, 100.0, 0.2, 9),
                sprand_spd_csr(200, 4, 4.0, 100.0, 0.2, 9),
            ),
        ];
        for (name, a, b) in &gens {
            assert_sdd_symmetric(a, 0.2);
            assert_eq!(a.nnz(), b.nnz(), "{name} not deterministic");
            assert_eq!(a.to_dense().data(), b.to_dense().data(), "{name}");
            // Genuinely sparse: far below 20% density at n=200.
            assert!(a.density() < 0.2, "{name} density {}", a.density());
        }
    }

    #[test]
    fn sparse_spd_condition_tracks_target() {
        use crate::matrices::MatrixSource;
        // Gershgorin pins kappa within (1+a)/(1-a) = 1.5x of target.
        let a = arrowhead_csr(120, 4.0, 50.0, 0.2, 3);
        let dense = a.block(0, 0, 120, 120);
        let k = cond::condition_number(&dense, 400, 7).unwrap();
        assert!(k >= 50.0 / 1.5 && k <= 50.0 * 1.6, "kappa={k}");
        let smax = cond::spectral_norm(&dense, 400, 8);
        assert!(smax <= 4.0 * 1.2 * 1.001 && smax >= 4.0 * 0.8, "smax={smax}");
    }

    #[test]
    fn arrowhead_nnz_formula() {
        let a = arrowhead_csr(64, 4.0, 10.0, 0.2, 1);
        // 5n - 6 structural entries unless a draw lands exactly on 0.0.
        assert!(a.nnz() <= 5 * 64 - 6 && a.nnz() >= 5 * 64 - 10, "{}", a.nnz());
    }
}
