//! In-house benchmark harness (criterion stand-in, DESIGN.md S13).
//!
//! `harness = false` bench targets use [`BenchRunner`] for wall-clock
//! measurement with warmup and robust statistics, plus the paper-table
//! emitters in [`crate::metrics::table`].  Figures are emitted as aligned
//! text series + CSV files under `bench_results/`.

use crate::metrics::mean_std;
use crate::runtime::Backend;
use std::time::Instant;

/// Backend for bench targets: the PJRT artifact path when available,
/// otherwise the native twin (override with `MELISO_BENCH_BACKEND=native`).
pub fn backend() -> Backend {
    use crate::runtime::native::NativeBackend;
    use crate::runtime::pjrt::default_artifact_dir;
    use crate::runtime::service::PjrtBackend;
    use std::sync::Arc;
    let forced = std::env::var("MELISO_BENCH_BACKEND").unwrap_or_default();
    if forced != "native" {
        match PjrtBackend::start(&default_artifact_dir()) {
            Ok(b) => {
                eprintln!("[backend: pjrt artifacts]");
                return Arc::new(b);
            }
            Err(e) => eprintln!("[backend: pjrt unavailable ({e}); using native]"),
        }
    } else {
        eprintln!("[backend: native (forced)]");
    }
    Arc::new(NativeBackend::new())
}

/// Timing statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn throughput_line(&self, items_per_iter: f64, unit: &str) -> String {
        format!(
            "{:<38} {:>10.4} ms/iter  (±{:.3} ms, min {:.3} ms, p95 {:.3} ms)  {:>12.1} {unit}/s",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.p95_s * 1e3,
            items_per_iter / self.mean_s.max(1e-12),
        )
    }
}

/// Wall-clock bench runner with warmup.
pub struct BenchRunner {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        // Modest defaults: full-fidelity experiment regeneration is the
        // figure benches' job; timing benches keep run time bounded.
        BenchRunner {
            warmup_iters: 2,
            sample_iters: 10,
        }
    }
}

impl BenchRunner {
    pub fn quick() -> Self {
        BenchRunner {
            warmup_iters: 1,
            sample_iters: 5,
        }
    }

    /// Measure `f` and return stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters.max(1) {
            // meliso-lint: allow(clock) -- bench harness stopwatch, measurement is the product
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (mean, std) = mean_std(&samples);
        let pct = |q: f64| sorted[((sorted.len() - 1) as f64 * q).round() as usize];
        BenchStats {
            name: name.to_string(),
            samples: samples.len(),
            mean_s: mean,
            std_s: std,
            min_s: sorted[0],
            p50_s: pct(0.5),
            p95_s: pct(0.95),
        }
    }
}

/// Parse common bench CLI flags (`--quick`, `--full`, `--reps N`,
/// `--out DIR`); bench targets share this tiny parser.
pub struct BenchArgs {
    pub quick: bool,
    pub full: bool,
    pub reps: usize,
    pub out_dir: String,
    /// Leftover free-form args (bench-specific).
    pub rest: Vec<String>,
}

impl BenchArgs {
    pub fn parse() -> BenchArgs {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> BenchArgs {
        let mut out = BenchArgs {
            quick: false,
            full: false,
            reps: 0,
            out_dir: "bench_results".to_string(),
            rest: Vec::new(),
        };
        let mut it = args.into_iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--quick" => out.quick = true,
                "--full" => out.full = true,
                "--reps" => {
                    if let Some(v) = it.next() {
                        out.reps = v.parse().unwrap_or(0);
                    }
                }
                "--out" => {
                    if let Some(v) = it.next() {
                        out.out_dir = v;
                    }
                }
                // `cargo bench` passes --bench; ignore harness plumbing.
                "--bench" => {}
                other => out.rest.push(other.to_string()),
            }
        }
        out
    }

    /// Replication count: explicit `--reps`, else quick/full presets.
    pub fn reps_or(&self, quick: usize, default: usize, full: usize) -> usize {
        if self.reps > 0 {
            self.reps
        } else if self.quick {
            quick
        } else if self.full {
            full
        } else {
            default
        }
    }

    /// Write a result file under the output directory.
    pub fn write_result(&self, filename: &str, content: &str) {
        let dir = std::path::Path::new(&self.out_dir);
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(filename);
            if std::fs::write(&path, content).is_ok() {
                println!("[saved {}]", path.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_collects_samples() {
        let r = BenchRunner::quick();
        let mut count = 0;
        let stats = r.run("noop", || count += 1);
        assert_eq!(stats.samples, 5);
        assert_eq!(count, 6); // warmup + samples
        assert!(stats.mean_s >= 0.0);
        assert!(stats.min_s <= stats.p95_s);
    }

    #[test]
    fn args_parse_flags() {
        let a = BenchArgs::parse_from(
            ["--quick", "--reps", "7", "--out", "/tmp/x", "--fig", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert!(a.quick);
        assert_eq!(a.reps, 7);
        assert_eq!(a.out_dir, "/tmp/x");
        assert_eq!(a.rest, vec!["--fig", "2"]);
        assert_eq!(a.reps_or(1, 2, 3), 7);
    }

    #[test]
    fn reps_presets() {
        let q = BenchArgs::parse_from(["--quick".to_string()]);
        assert_eq!(q.reps_or(1, 2, 3), 1);
        let d = BenchArgs::parse_from(Vec::<String>::new());
        assert_eq!(d.reps_or(1, 2, 3), 2);
        let f = BenchArgs::parse_from(["--full".to_string()]);
        assert_eq!(f.reps_or(1, 2, 3), 3);
    }
}
