//! Panic-injection regression suite for the execution plane's supervised
//! gather (the ROADMAP's former known limitation: a shard that panicked
//! mid-walk hung the resident-path gather forever).
//!
//! Every scenario runs under a hard wall-clock bound: the operation
//! executes on a helper thread and the test fails — instead of hanging
//! CI — if no result arrives in time.  Faults are injected through
//! `meliso::testing::faults`:
//!
//! * [`PanicSource`] — the *leader-side* walk panics extracting a chosen
//!   chunk (corrupt operand);
//! * [`FaultBackend::panicking`] — a *shard thread* panics mid-read (the
//!   original hang);
//! * recovery: after a shard panic the plane is failed and every call
//!   returns a clean error; after a leader-side extraction panic the
//!   plane stays serviceable.

use meliso::matrices::{DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::testing::faults::{FaultBackend, PanicSource};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Hard bound on any single scenario: generous for slow CI runners, tiny
/// against the infinite hang this suite guards against.
const SCENARIO_TIMEOUT: Duration = Duration::from_secs(120);

/// Run `f` on a helper thread and fail the test if it does not finish in
/// [`SCENARIO_TIMEOUT`] — a regression of the hang fix trips this bound
/// instead of wedging the whole test run.
fn bounded<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::Builder::new()
        .name(format!("bounded-{name}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn scenario thread");
    match rx.recv_timeout(SCENARIO_TIMEOUT) {
        Ok(v) => v,
        Err(_) => panic!("scenario {name:?} hung past {SCENARIO_TIMEOUT:?} (hang-fix regression)"),
    }
}

fn config() -> SystemConfig {
    SystemConfig::new(2, 2, 32)
}

fn opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_workers(2)
        .with_seed(11)
}

fn dense(seed: u64) -> Matrix {
    Matrix::standard_normal(64, 64, seed)
}

#[test]
fn one_shot_leader_extraction_panic_is_clean_error() {
    let err = bounded("one-shot/leader-panic", || {
        // Poison the chunk at (32, 0): the leader's streaming extraction
        // panics mid-walk.
        let src = PanicSource::new(dense(1), (32, 0));
        let x = Vector::standard_normal(64, 2);
        let plane =
            ExecutionPlane::build(&src, &config(), &opts(), Arc::new(NativeBackend::new()))
                .unwrap();
        plane.execute_once(&src, &x).unwrap_err()
    });
    assert!(err.to_string().contains("panicked"), "{err}");
    assert!(err.to_string().contains("poisoned block"), "{err}");
}

#[test]
fn resident_program_leader_panic_is_clean_error_and_plane_recovers() {
    bounded("resident/program-leader-panic", || {
        let poisoned = PanicSource::new(dense(3), (32, 32));
        let clean = DenseSource::new(dense(4));
        let plane =
            PlaneHandle::build(&poisoned, &config(), &opts(), Arc::new(NativeBackend::new()))
                .unwrap();
        let err = plane.program(&poisoned).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // A leader-side extraction fault is recoverable: the partial
        // residency was retired (slots freed) and the pool still serves.
        assert_eq!(plane.resident_operands(), 0);
        assert_eq!(plane.slots_in_use(), 0);
        let (id, program) = plane.program(&clean).unwrap();
        assert_eq!(program.chunks_resident, 4);
        let x = Vector::standard_normal(64, 5);
        let batch = plane.execute_batch(id, std::slice::from_ref(&x)).unwrap();
        assert_eq!(batch.solves.len(), 1);
    });
}

#[test]
fn one_shot_shard_panic_is_clean_error() {
    let err = bounded("one-shot/shard-panic", || {
        // The backend panics inside the shard thread on every tile read —
        // the exact failure that used to hang the gather.
        let src = DenseSource::new(dense(6));
        let x = Vector::standard_normal(64, 7);
        let backend = FaultBackend::panicking(NativeBackend::new()).armed();
        let plane =
            ExecutionPlane::build(&src, &config(), &opts(), Arc::new(backend)).unwrap();
        plane.execute_once(&src, &x).unwrap_err()
    });
    assert!(err.to_string().contains("panicked"), "{err}");
}

#[test]
fn resident_execute_shard_panic_is_clean_error_and_fails_fast_after() {
    bounded("resident/execute-shard-panic", || {
        let src = DenseSource::new(dense(8));
        let backend = FaultBackend::panicking(NativeBackend::new());
        let handle = backend.handle();
        let plane = PlaneHandle::build(&src, &config(), &opts(), Arc::new(backend)).unwrap();
        // Programming does not touch the backend; arm afterwards so the
        // panic fires inside a shard's execute walk.
        let (id, _) = plane.program(&src).unwrap();
        handle.fail_next_reads(true);
        let x = Vector::standard_normal(64, 9);
        let err = plane
            .execute_batch(id, std::slice::from_ref(&x))
            .unwrap_err();
        assert!(
            matches!(err, PlaneError::ShardDead(_) | PlaneError::Failed(_)),
            "{err:?}"
        );
        assert!(err.to_string().contains("panicked"), "{err}");
        // The pool lost a worker: the plane is failed, and every later
        // call is an immediate clean error (fail fast, never hang).
        assert!(plane.failure().is_some());
        handle.fail_next_reads(false);
        let err2 = plane
            .execute_batch(id, std::slice::from_ref(&x))
            .unwrap_err();
        assert!(err2.to_string().contains("failed"), "{err2}");
        let err3 = plane.program(&src).unwrap_err();
        assert!(err3.to_string().contains("failed"), "{err3}");
    });
}

#[test]
fn resident_session_surfaces_shard_panic_as_error() {
    bounded("resident/session-shard-panic", || {
        let backend = FaultBackend::panicking(NativeBackend::new());
        let handle = backend.handle();
        let src: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(dense(10)));
        let session = Session::open(src, config(), opts(), Arc::new(backend)).unwrap();
        let x = Vector::standard_normal(64, 11);
        assert!(session.solve(&x).is_ok());
        handle.fail_next_reads(true);
        let err = session.solve(&x).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // The session keeps reporting (stats survive) and keeps failing
        // cleanly rather than hanging.
        assert_eq!(session.report().errors, 1);
        assert!(session.solve(&x).is_err());
    });
}

#[test]
fn dead_shard_mid_gather_errors_within_supervision_bound() {
    // Regression for the blocking-recv audit: a shard that dies while a
    // gather is outstanding must surface an error within the supervision
    // window, not park forever on a channel nobody will ever write.  The
    // wall-clock assertion is deliberately generous (60 s on a gather
    // that should fail in milliseconds) — it exists to catch a return to
    // unbounded waiting, not to benchmark the failure path.
    let elapsed = bounded("resident/dead-shard-bounded-gather", || {
        let src = DenseSource::new(dense(15));
        let backend = FaultBackend::panicking(NativeBackend::new());
        let handle = backend.handle();
        let plane = PlaneHandle::build(&src, &config(), &opts(), Arc::new(backend)).unwrap();
        let (id, _) = plane.program(&src).unwrap();
        handle.fail_next_reads(true);
        let x = Vector::standard_normal(64, 16);
        let t0 = std::time::Instant::now();
        let err = plane
            .execute_batch(id, std::slice::from_ref(&x))
            .unwrap_err();
        let elapsed = t0.elapsed();
        assert!(err.to_string().contains("panicked"), "{err}");
        elapsed
    });
    assert!(
        elapsed < Duration::from_secs(60),
        "dead-shard gather took {elapsed:?}: supervision bound regressed"
    );
}

#[test]
fn multi_tenant_plane_survives_leader_fault_in_one_tenant() {
    bounded("resident/multi-tenant-isolation", || {
        let good: Arc<dyn MatrixSource> = Arc::new(DenseSource::new(dense(12)));
        let poisoned = PanicSource::new(dense(13), (0, 32));
        let plane = PlaneHandle::build(
            good.as_ref(),
            &config(),
            &opts(),
            Arc::new(NativeBackend::new()),
        )
        .unwrap();
        let good_session = Session::open_on(plane.clone(), good).unwrap();
        // A tenant whose operand is corrupt fails to open ...
        let err = Session::open_on(plane.clone(), Arc::new(poisoned)).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        // ... without disturbing the healthy tenant.
        let x = Vector::standard_normal(64, 14);
        assert!(good_session.solve(&x).is_ok());
        assert_eq!(plane.resident_operands(), 1);
    });
}
