//! End-to-end battery for the network serving front door: a real
//! `meliso::serve::Server` on an ephemeral port, driven by a std-only
//! test HTTP client over `TcpStream`.
//!
//! The load-bearing assertions are bit-identity ones: a solve answered
//! through upload → coalescing window → `solve_batch` → JSON must equal,
//! bit for bit, the same solve issued directly against a resident
//! [`Session`] on an identically-seeded solver.  The JSON layer is
//! exact by construction (the vendored writer emits shortest
//! round-trip f64), so any mismatch is a serving-path bug, not a
//! formatting artifact.

use meliso::linalg::Vector;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::serve::{ServeConfig, Server};
use meliso::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn solver() -> Meliso {
    Meliso::with_backend(
        SystemConfig::new(2, 2, 32),
        SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_workers(2)
            .with_seed(11),
        Arc::new(NativeBackend::new()),
    )
}

fn server() -> Server {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        http_threads: 4,
        ..ServeConfig::default()
    };
    Server::start(solver(), cfg).unwrap()
}

/// Minimal std-only HTTP client: one request, one connection
/// (the server speaks `Connection: close`), bounded socket timeouts so
/// a server bug fails the test instead of hanging it.
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    client_id: &str,
    body: &[u8],
) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    conn.set_write_timeout(Some(Duration::from_secs(60))).unwrap();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: meliso-test\r\nX-Client-Id: {client_id}\r\n\
         Content-Length: {}\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes()).unwrap();
    conn.write_all(body).unwrap();
    conn.flush().unwrap();
    let mut raw = String::new();
    conn.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response: {raw}"));
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn solve_body(x: &Vector) -> String {
    let mut doc = Json::obj();
    doc.set(
        "x",
        Json::Arr(x.data().iter().map(|&v| Json::Num(v)).collect()),
    );
    doc.compact()
}

fn parse_solve(body: &str) -> (u64, Vec<f64>) {
    let doc = Json::parse(body).unwrap();
    let index = doc.get("solve_index").unwrap().as_f64().unwrap() as u64;
    let y = doc
        .get("y")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    (index, y)
}

fn upload(addr: SocketAddr, client: &str, body: &[u8]) -> String {
    let (status, resp) = http(addr, "POST", "/operands", client, body);
    assert_eq!(status, 200, "{resp}");
    Json::parse(&resp)
        .unwrap()
        .get("operand")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

fn arrow16_mtx() -> Vec<u8> {
    std::fs::read(Path::new(env!("CARGO_MANIFEST_DIR")).join("data/arrow16.mtx")).unwrap()
}

#[test]
fn upload_solve_evict_round_trip_matches_direct_session() {
    let server = server();
    let addr = server.addr();
    let handle = upload(addr, "e2e-a", &arrow16_mtx());

    // Direct reference: an identically-seeded solver, the same operand
    // through the same registry route, sequential solves 0..N.
    let src = registry::build(&format!(
        "mtx:{}",
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("data/arrow16.mtx")
            .display()
    ))
    .unwrap();
    let reference_session = solver().open_session(src).unwrap();

    let xs: Vec<Vector> = (0..4).map(|s| Vector::standard_normal(16, 300 + s)).collect();
    for (k, x) in xs.iter().enumerate() {
        let (status, resp) = http(
            addr,
            "POST",
            &format!("/operands/{handle}/solve"),
            "e2e-a",
            solve_body(x).as_bytes(),
        );
        assert_eq!(status, 200, "{resp}");
        let (index, y) = parse_solve(&resp);
        assert_eq!(index, k as u64);
        let direct = reference_session.solve(x).unwrap();
        assert_eq!(direct.solve_index, k as u64);
        assert_eq!(y, direct.y.data(), "solve {k} diverged from direct session");
    }

    // Evict, then the handle is gone.
    let (status, _) = http(addr, "DELETE", &format!("/operands/{handle}"), "e2e-a", b"");
    assert_eq!(status, 200);
    let (status, resp) = http(
        addr,
        "POST",
        &format!("/operands/{handle}/solve"),
        "e2e-a",
        solve_body(&xs[0]).as_bytes(),
    );
    assert_eq!(status, 404, "{resp}");

    // The front door observed itself: /status carries the serve section.
    let (status, resp) = http(addr, "GET", "/status", "e2e-a", b"");
    assert_eq!(status, 200);
    let report = Json::parse(&resp).unwrap();
    let requests = report
        .get("serve")
        .unwrap()
        .get("requests")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(requests >= 7.0, "serve.requests = {requests}");
    server.shutdown();
}

#[test]
fn concurrent_clients_on_one_operand_coalesce_bit_identically() {
    let server = server();
    let addr = server.addr();
    let handle = upload(addr, "seed", b"{\"name\": \"spd64\"}");

    // Every client solves the SAME vector, so y depends only on the
    // solve index the window assigned: y_k = f(x, k).  The sequential
    // reference enumerates exactly those values.
    const THREADS: usize = 4;
    const PER_THREAD: usize = 3;
    let x = Vector::standard_normal(64, 99);
    let reference: Vec<Vec<f64>> = {
        let session = solver().open_session(registry::build("spd64").unwrap()).unwrap();
        (0..THREADS * PER_THREAD)
            .map(|_| session.solve(&x).unwrap().y.data().to_vec())
            .collect()
    };

    let collected: Arc<Mutex<Vec<(u64, Vec<f64>)>>> = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let collected = collected.clone();
            let handle = handle.clone();
            let x = x.clone();
            s.spawn(move || {
                for _ in 0..PER_THREAD {
                    let (status, resp) = http(
                        addr,
                        "POST",
                        &format!("/operands/{handle}/solve"),
                        &format!("client-{t}"),
                        solve_body(&x).as_bytes(),
                    );
                    assert_eq!(status, 200, "{resp}");
                    collected.lock().unwrap().push(parse_solve(&resp));
                }
            });
        }
    });

    let mut results = Arc::try_unwrap(collected).unwrap().into_inner().unwrap();
    results.sort_by_key(|(index, _)| *index);
    // Exactly-once completion: every solve index 0..N, no dup, no gap.
    let indices: Vec<u64> = results.iter().map(|(i, _)| *i).collect();
    assert_eq!(indices, (0..(THREADS * PER_THREAD) as u64).collect::<Vec<_>>());
    for (index, y) in &results {
        assert_eq!(
            y,
            &reference[*index as usize],
            "coalesced solve {index} diverged from sequential reference"
        );
    }
    server.shutdown();
}

#[test]
fn threads_over_distinct_operands_match_sequential_reference() {
    let server = server();
    let addr = server.addr();
    let operands: [(&str, usize); 3] = [("spd64", 64), ("nonsym64", 64), ("iperturb66", 66)];

    // Per-operand arrival order is each thread's own request order, so
    // solve indices are 0..K per operand and inputs can differ.
    std::thread::scope(|s| {
        for (t, (name, n)) in operands.iter().enumerate() {
            s.spawn(move || {
                let handle = upload(
                    addr,
                    &format!("tenant-{t}"),
                    format!("{{\"name\": \"{name}\"}}").as_bytes(),
                );
                let reference_session = solver()
                    .open_session(registry::build(name).unwrap())
                    .unwrap();
                for k in 0..3u64 {
                    let x = Vector::standard_normal(*n, 500 + 10 * t as u64 + k);
                    let (status, resp) = http(
                        addr,
                        "POST",
                        &format!("/operands/{handle}/solve"),
                        &format!("tenant-{t}"),
                        solve_body(&x).as_bytes(),
                    );
                    assert_eq!(status, 200, "{resp}");
                    let (index, y) = parse_solve(&resp);
                    assert_eq!(index, k);
                    let direct = reference_session.solve(&x).unwrap();
                    assert_eq!(y, direct.y.data(), "{name} solve {k} diverged");
                }
            });
        }
    });
    server.shutdown();
}

#[test]
fn repeat_boot_with_same_seed_is_deterministic() {
    // The whole served sequence — program, coalesce, solve — replays
    // bit-identically on a fresh server with the same solver seed.
    // (Only the payload is compared: `wall_seconds` is a measurement.)
    let run = || -> Vec<(u64, Vec<f64>)> {
        let server = server();
        let addr = server.addr();
        let handle = upload(addr, "det", &arrow16_mtx());
        let out = (0..3)
            .map(|s| {
                let x = Vector::standard_normal(16, 700 + s);
                let (status, resp) = http(
                    addr,
                    "POST",
                    &format!("/operands/{handle}/solve"),
                    "det",
                    solve_body(&x).as_bytes(),
                );
                assert_eq!(status, 200, "{resp}");
                parse_solve(&resp)
            })
            .collect();
        server.shutdown();
        out
    };
    assert_eq!(run(), run(), "served solves are not deterministic under a fixed seed");
}
