//! Property-based tests over coordinator/EC/virtualization invariants,
//! driven by the in-house mini-framework (`meliso::testing`).

use meliso::device::materials::Material;
use meliso::ec::{EcOptions, TileExecutor};
use meliso::linalg::tridiag::Tridiag;
use meliso::linalg::{Matrix, Vector};
use meliso::matrices::{DenseSource, MatrixSource};
use meliso::mca::{Mca, WriteVerifyOpts};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::testing::{gen, PropRunner};
use meliso::virtualization::{ChunkPlan, SystemGeometry};
use std::sync::Arc;

#[test]
fn prop_chunk_plan_covers_operand_exactly_once() {
    PropRunner::new(64, 101).run("chunk-coverage", |rng, _| {
        let tile_rows = 1 + rng.below(6);
        let tile_cols = 1 + rng.below(6);
        let cell = *gen::choice(rng, &[16usize, 32, 64]);
        let m = 1 + rng.below(1200);
        let n = 1 + rng.below(1200);
        let plan = ChunkPlan::new(SystemGeometry::new(tile_rows, tile_cols, cell), m, n);
        // Every operand coordinate is covered by exactly one chunk.
        let mut cover = vec![0u8; plan.grid_rows * plan.grid_cols];
        for c in plan.chunks() {
            let idx = c.block_row * plan.grid_cols + c.block_col;
            cover[idx] += 1;
            if c.row0 % cell != 0 || c.col0 % cell != 0 {
                return Err(format!("misaligned chunk at ({}, {})", c.row0, c.col0));
            }
            if c.mca_index >= tile_rows * tile_cols {
                return Err("MCA index out of range".into());
            }
        }
        if cover.iter().any(|&c| c != 1) {
            return Err("chunk grid not covered exactly once".into());
        }
        // Padded dims fit capacity times reassignments.
        let (pm, pn) = plan.padded_dims();
        if pm < m || pn < n {
            return Err("padding smaller than operand".into());
        }
        Ok(())
    });
}

#[test]
fn prop_assignments_balanced_round_robin() {
    PropRunner::new(48, 102).run("assignment-balance", |rng, _| {
        let r = 1 + rng.below(8);
        let c = 1 + rng.below(8);
        let cell = 32;
        let m = 1 + rng.below(2000);
        let plan = ChunkPlan::new(SystemGeometry::new(r, c, cell), m, m);
        let counts = plan.assignments_per_mca();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        // Round-robin balance: the spread between any two MCAs is bounded
        // by the per-dimension remainder (max load <= ceil products).
        let bound = plan.row_reassignments()
            * meliso::util::ceil_div(plan.grid_cols, c);
        if max > bound {
            return Err(format!("max load {max} exceeds bound {bound}"));
        }
        if max > 0 && min + 2 * bound < max {
            return Err(format!("unbalanced: min {min}, max {max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_denoise_operator_solve_inverts() {
    PropRunner::new(32, 103).run("tridiag-inverse", |rng, _| {
        let n = 2 + rng.below(120);
        let lambda = 10f64.powf(rng.uniform_range(-12.0, 0.0));
        let t = Tridiag::denoise_operator(n, lambda, -1.0);
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b = t.matvec(&x);
        let got = t.solve(&b);
        for i in 0..n {
            if (got[i] - x[i]).abs() > 1e-8 * (1.0 + x[i].abs()) {
                return Err(format!("solve mismatch at {i}: {} vs {}", got[i], x[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_error_bounded_and_sign_preserving() {
    PropRunner::new(24, 104).run("encode-bounds", |rng, case| {
        let material = gen::material(rng);
        let n = *gen::choice(rng, &[16usize, 32, 64]);
        let a = gen::scaled_matrix(rng, n);
        let mut mca = Mca::new(material, n, n, 900 + case as u64);
        let enc = mca.set_weights(&a);
        let p = material.params();
        let scale = a.max_abs();
        let band = scale * (4.0 * (p.sigma_prog + p.sigma_d2d) + p.level_step());
        for (w, e) in a.data().iter().zip(enc.data()) {
            if (w - e).abs() > band * (1.0 + w.abs() / scale) {
                return Err(format!("encode error too large: w={w}, enc={e}"));
            }
            // Zero stays exactly zero (differential pair parked).
            if *w == 0.0 && *e != 0.0 {
                return Err("zero cell perturbed".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_first_order_correction_never_worse_than_raw() {
    // Across materials / scales / sizes, the EC output must beat the raw
    // product (with margin, since both are stochastic).
    PropRunner::new(10, 105).run("ec-dominates-raw", |rng, case| {
        let material = gen::material(rng);
        let n = *gen::choice(rng, &[32usize, 64]);
        let a = gen::scaled_matrix(rng, n);
        let x = gen::vector(rng, n);
        let b = a.matvec(&x);
        let backend = Arc::new(NativeBackend::new());
        let seed = 7000 + case as u64;

        let raw = {
            let mut te = TileExecutor::new(Mca::new(material, n, n, seed), backend.clone());
            let opts = EcOptions {
                ec: false,
                ..EcOptions::default()
            };
            te.run_tile(&a, &x, &opts).unwrap().y
        };
        let ec = {
            let mut te = TileExecutor::new(Mca::new(material, n, n, seed + 1), backend.clone());
            let mut opts = EcOptions::default();
            opts.wv = WriteVerifyOpts::default().with_iters(2);
            te.run_tile(&a, &x, &opts).unwrap().y
        };
        let rel = |y: &Vector| y.sub(&b).norm_l2() / b.norm_l2();
        let (r_raw, r_ec) = (rel(&raw), rel(&ec));
        if r_ec > r_raw * 0.9 {
            return Err(format!(
                "{material} n={n}: ec {r_ec:.4} not better than raw {r_raw:.4}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_solve_report_metrics_consistent() {
    PropRunner::new(8, 106).run("report-consistency", |rng, case| {
        let n = *gen::choice(rng, &[48usize, 96]);
        let a = Matrix::standard_normal(n, n, 300 + case as u64);
        let x = gen::vector(rng, n);
        let tiles = 1 + rng.below(2);
        let solver = Meliso::with_backend(
            SystemConfig::new(tiles, tiles, 32),
            SolveOptions::default()
                .with_device(gen::material(rng))
                .with_workers(1 + rng.below(4))
                .with_seed(case as u64),
            Arc::new(NativeBackend::new()),
        );
        let report = solver.solve(&a, &x).map_err(|e| e.to_string())?;
        if report.y.len() != n {
            return Err("result length mismatch".into());
        }
        if report.chunks_skipped > report.chunks_total {
            return Err("skipped > total".into());
        }
        if report.mcas_used > tiles * tiles {
            return Err("more MCAs used than exist".into());
        }
        if report.ew_total + 1e-18 < report.ew_mean * report.mcas_used as f64 * 0.999 {
            return Err("energy mean/total inconsistent".into());
        }
        if report.lw_max + 1e-18 < report.lw_mean * 0.999 {
            return Err("latency max < mean".into());
        }
        if !report.rel_err_l2.is_finite() || report.rel_err_l2 < 0.0 {
            return Err("bad error metric".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sparsity_skipping_never_changes_results_much() {
    // Skipping all-zero chunks must be output-equivalent to processing
    // them (zero tiles contribute exactly zero current).
    PropRunner::new(6, 107).run("skip-equivalence", |rng, case| {
        let n = 128;
        let band = 4 + rng.below(8);
        let src = meliso::matrices::BandedSource::new(n, band, 1.0, 10.0, 0.2, case as u64);
        let dense = DenseSource::new(src.block(0, 0, n, n));
        let x = gen::vector(rng, n);
        let mk = || {
            Meliso::with_backend(
                SystemConfig::new(2, 2, 32),
                SolveOptions::default()
                    .with_device(Material::EpiRam)
                    .with_seed(4242 + case as u64),
                Arc::new(NativeBackend::new()),
            )
        };
        let with_skip = mk().solve_source(&src, &x).map_err(|e| e.to_string())?;
        let without = mk().solve_source(&dense, &x).map_err(|e| e.to_string())?;
        if with_skip.chunks_skipped == 0 {
            return Err("expected some skipped chunks".into());
        }
        let diff = with_skip.y.sub(&without.y).norm_l2() / without.y.norm_l2().max(1e-9);
        // Not bit-identical (different RNG consumption order) but both are
        // valid device-noise draws of the same computation.
        if diff > 0.2 {
            return Err(format!("skip changed result by {diff}"));
        }
        Ok(())
    });
}

#[test]
fn prop_csr_agrees_with_dense_reference() {
    use meliso::matrices::CsrSource;
    // block / matvec / occupied_cols / block_is_zero against a dense
    // reference on random sparse matrices: empty rows, duplicate
    // triplets, tail tiles and non-square shapes included.
    PropRunner::new(48, 108).run("csr-dense-agreement", |rng, case| {
        let m = 1 + rng.below(120);
        let n = 1 + rng.below(120);
        // Density sweep from nearly-empty to ~quarter full.
        let count = rng.below(1 + (m * n) / 4);
        let trip: Vec<(usize, usize, f64)> = (0..count)
            .map(|_| (rng.below(m), rng.below(n), rng.uniform_range(-2.0, 2.0)))
            .collect();
        let csr = CsrSource::from_triplets(m, n, &trip).map_err(|e| e.to_string())?;
        let mut dense = Matrix::zeros(m, n);
        for &(i, j, v) in &trip {
            dense.set(i, j, dense.get(i, j) + v);
        }

        // matvec agrees to f64 roundoff.
        let x = gen::vector(rng, n);
        let ya = csr.matvec(&x);
        let yd = dense.matvec(&x);
        for (idx, (a, d)) in ya.data().iter().zip(yd.data()).enumerate() {
            if (a - d).abs() > 1e-10 {
                return Err(format!("case {case}: matvec row {idx}: {a} vs {d}"));
            }
        }

        // Random blocks (including ones hanging past both edges).
        for _ in 0..8 {
            let r0 = rng.below(m + 8);
            let c0 = rng.below(n + 8);
            let h = 1 + rng.below(40);
            let w = 1 + rng.below(40);
            let got = csr.block(r0, c0, h, w);
            let want = dense.block_padded(r0, c0, h, w);
            if got != want {
                return Err(format!("case {case}: block ({r0},{c0},{h},{w}) mismatch"));
            }
            let structurally_zero = csr.block_is_zero(r0, c0, h, w);
            let actually_zero = want.data().iter().all(|&v| v == 0.0);
            if structurally_zero != actually_zero {
                return Err(format!(
                    "case {case}: block_is_zero({r0},{c0},{h},{w}) = {structurally_zero}, \
                     dense says {actually_zero}"
                ));
            }
        }

        // occupied_cols covers every nonzero column of the row range, and
        // is tight at both ends (or empty when the rows are empty).
        for _ in 0..4 {
            let r0 = rng.below(m + 4);
            let rows = 1 + rng.below(24);
            let (lo, hi) = csr.occupied_cols(r0, rows);
            let mut seen: Option<(usize, usize)> = None;
            for i in r0..(r0 + rows).min(m) {
                for j in 0..n {
                    if dense.get(i, j) != 0.0 {
                        let (a, b) = seen.unwrap_or((j, j));
                        seen = Some((a.min(j), b.max(j)));
                    }
                }
            }
            match seen {
                None => {
                    if lo < hi {
                        return Err(format!("case {case}: empty rows reported [{lo},{hi})"));
                    }
                }
                Some((first, last)) => {
                    if (lo, hi) != (first, last + 1) {
                        return Err(format!(
                            "case {case}: occupied_cols [{lo},{hi}) not tight vs \
                             [{first},{})",
                            last + 1
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_planned_chunks_match_filtered_grid_walk() {
    use meliso::matrices::{generators, CsrSource};
    // For irregular patterns, the streaming enumeration must visit
    // exactly the chunks a filtered full-grid walk would, in the same
    // deterministic row-major order.
    PropRunner::new(24, 109).run("csr-planning-equivalence", |rng, case| {
        let n = 64 + rng.below(256);
        let kind = rng.below(4);
        let src: CsrSource = match kind {
            0 => generators::arrowhead_csr(n, 4.0, 50.0, 0.2, case as u64),
            1 => generators::power_law_csr(n, 3, 4.0, 50.0, 0.2, case as u64),
            2 => generators::block_diag_csr(n, 32, 4.0, 50.0, 0.2, case as u64),
            _ => generators::sprand_spd_csr(n, 3, 4.0, 50.0, 0.2, case as u64),
        };
        let cell = *gen::choice(rng, &[16usize, 32]);
        let tiles = 1 + rng.below(4);
        let plan = ChunkPlan::new(SystemGeometry::new(tiles, tiles, cell), n, n);
        let full: Vec<(usize, usize)> = plan
            .chunks()
            .filter(|c| !src.block_is_zero(c.row0, c.col0, cell, cell))
            .map(|c| (c.block_row, c.block_col))
            .collect();
        let streamed: Vec<(usize, usize)> = plan
            .nonzero_chunks(&src)
            .map(|c| (c.block_row, c.block_col))
            .collect();
        if full != streamed {
            return Err(format!(
                "case {case} (kind {kind}, n {n}, cell {cell}): streamed {} chunks, \
                 filtered walk {}",
                streamed.len(),
                full.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_descriptor_chunk_encodes_bit_identical_to_leader_extraction() {
    use meliso::matrices::generators;
    // A shard materializing a chunk straight from the CSR source (the
    // descriptor path) must produce the exact zero-padded tile the leader
    // would have extracted from a dense materialization — and feeding
    // either tile to a same-seeded MCA must yield bit-identical
    // conductance encodings.
    PropRunner::new(24, 110).run("descriptor-encode-identity", |rng, case| {
        let n = 64 + rng.below(192);
        let src = generators::power_law_csr(n, 3, 4.0, 50.0, 0.2, 1000 + case as u64);
        let cell = *gen::choice(rng, &[16usize, 32]);
        let full = DenseSource::new(src.block(0, 0, n, n));
        let material = gen::material(rng);
        for _ in 0..6 {
            let r0 = rng.below(1 + n / cell) * cell;
            let c0 = rng.below(1 + n / cell) * cell;
            let desc_tile = src.block(r0, c0, cell, cell);
            let dense_tile = full.block(r0, c0, cell, cell);
            if desc_tile != dense_tile {
                return Err(format!("case {case}: tile ({r0},{c0}) extraction differs"));
            }
            let seed = 2000 + case as u64;
            let mut mca_a = Mca::new(material, cell, cell, seed);
            let mut mca_b = Mca::new(material, cell, cell, seed);
            if mca_a.set_weights(&desc_tile) != mca_b.set_weights(&dense_tile) {
                return Err(format!("case {case}: tile ({r0},{c0}) encoding differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shard_materialization_matches_leader_extraction_end_to_end() {
    use meliso::matrices::generators;
    // One-shot walks over a borrowed source (leader extracts dense tiles)
    // and over a shared source (shards materialize from descriptors) must
    // be bit-identical across random operands, geometries and worker
    // counts.
    PropRunner::new(8, 111).run("descriptor-walk-identity", |rng, case| {
        let n = 48 + rng.below(160);
        let src: Arc<dyn MatrixSource> = match rng.below(3) {
            0 => Arc::new(generators::power_law_csr(n, 3, 4.0, 50.0, 0.2, 3000 + case as u64)),
            1 => Arc::new(generators::arrowhead_csr(n, 4.0, 50.0, 0.2, 3000 + case as u64)),
            _ => Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 3000 + case as u64))),
        };
        let config = SystemConfig::new(1 + rng.below(3), 1 + rng.below(3), 32);
        let opts = SolveOptions::default()
            .with_device(gen::material(rng))
            .with_seed(5000 + case as u64)
            .with_workers(1 + rng.below(4));
        let x = gen::vector(rng, n);
        let backend = Arc::new(NativeBackend::new());
        let leader = PlaneHandle::build(src.as_ref(), &config, &opts, backend.clone())
            .map_err(|e| e.to_string())?
            .execute_once(src.as_ref(), &x)
            .map_err(|e| e.to_string())?;
        let shard = PlaneHandle::build(src.as_ref(), &config, &opts, backend)
            .map_err(|e| e.to_string())?
            .execute_once_shared(src.clone(), &x)
            .map_err(|e| e.to_string())?;
        if leader.y != shard.y {
            return Err(format!("case {case}: one-shot descriptor walk diverged"));
        }
        Ok(())
    });
}
