//! Concurrency models for the execution plane, checked over **every**
//! interleaving by the exhaustive explorer in `meliso::testing::sched`
//! (the repo's vendored loom stand-in — see that module's docs for why
//! loom itself is not in the build closure).
//!
//! Two designs get modeled, each in two variants:
//!
//! 1. **Two-tier steal cursors** (`plane/shard.rs`): workers claim MCAs
//!    from per-queue tier-1 cursors, drain each MCA's chunks through a
//!    tier-2 cursor, then sub-MCA-steal chunks from busy MCAs.  The
//!    faithful model (every cursor claim is one `fetch_add` step) must
//!    show every chunk claimed **exactly once** in every schedule.  A
//!    deliberately broken variant splits the stealer's claim into a read
//!    step and a write step; the explorer must find the double-claim,
//!    proving the harness actually has teeth.
//!
//! 2. **`InflightGuard` vs `evict`** (`plane/handle.rs`): admission
//!    checks residency and bumps the inflight count under one structural
//!    lock; evict checks the inflight count under the same lock and
//!    surfaces `OperandBusy` instead of removing a residency that a
//!    batch is using.  The faithful model never executes against an
//!    evicted residency; the broken variant (check residency, release
//!    the lock, then bump inflight) must be caught as a torn residency.
//!
//! The tests always run; `RUSTFLAGS="--cfg loom"` (the CI static-analysis
//! job) scales the thread counts up for a larger state space.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

use meliso::testing::sched::{explore, Model};

// ---------------------------------------------------------------------------
// Model 1: two-tier steal cursors
// ---------------------------------------------------------------------------

/// One worker's control state.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum W {
    /// Claiming an MCA from queue `(tid + scan) % queues` (tier 1).
    Scan { scan: u8 },
    /// Draining chunks of an exclusively claimed MCA (tier 2, owner).
    Drain { mca: u8 },
    /// Sub-MCA stealing: scanning MCA `scan` for leftover chunks.
    Steal { scan: u8 },
    /// Racy-variant only: holds a stale tier-2 cursor read, write pending.
    StealWrite { scan: u8, pending: u8 },
    Done,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct StealModel {
    /// When set, stealers claim with a read step then a write step
    /// instead of one atomic step — the bug the real design excludes.
    racy_steal: bool,
    queues: u8,
    mcas_per_queue: u8,
    /// Chunks per MCA.
    chunks: u8,
    /// Tier-1 cursor per queue (next unclaimed MCA offset).
    t1: Vec<u8>,
    /// Tier-2 cursor per MCA (next unclaimed chunk).
    t2: Vec<u8>,
    /// Claim count per chunk, indexed `mca * chunks + chunk`.
    claims: Vec<u8>,
    workers: Vec<W>,
}

impl StealModel {
    fn new(workers: usize, queues: u8, mcas_per_queue: u8, chunks: u8, racy: bool) -> StealModel {
        let mcas = (queues * mcas_per_queue) as usize;
        StealModel {
            racy_steal: racy,
            queues,
            mcas_per_queue,
            chunks,
            t1: vec![0; queues as usize],
            t2: vec![0; mcas],
            claims: vec![0; mcas * chunks as usize],
            workers: vec![W::Scan { scan: 0 }; workers],
        }
    }

    fn chunk_index(&self, mca: usize, chunk: u8) -> usize {
        mca * self.chunks as usize + chunk as usize
    }
}

impl Model for StealModel {
    fn runnable(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&t| self.workers[t] != W::Done)
            .collect()
    }

    fn step(&mut self, tid: usize) {
        match self.workers[tid] {
            W::Scan { scan } => {
                let q = ((tid as u8) + scan) % self.queues;
                if self.t1[q as usize] < self.mcas_per_queue {
                    // Tier-1 claim is a fetch_add: one step.
                    let mca = q * self.mcas_per_queue + self.t1[q as usize];
                    self.t1[q as usize] += 1;
                    self.workers[tid] = W::Drain { mca };
                } else if scan + 1 < self.queues {
                    self.workers[tid] = W::Scan { scan: scan + 1 };
                } else {
                    // Every queue exhausted: fall through to sub-MCA steal.
                    self.workers[tid] = W::Steal { scan: 0 };
                }
            }
            W::Drain { mca } => {
                let m = mca as usize;
                if self.t2[m] < self.chunks {
                    // Owner's tier-2 claim is a fetch_add: one step.
                    let idx = self.chunk_index(m, self.t2[m]);
                    self.claims[idx] += 1;
                    self.t2[m] += 1;
                } else {
                    self.workers[tid] = W::Scan { scan: 0 };
                }
            }
            W::Steal { scan } => {
                let m = scan as usize;
                if m >= self.t2.len() {
                    self.workers[tid] = W::Done;
                } else if self.t2[m] < self.chunks {
                    if self.racy_steal {
                        // BUG variant: read the cursor now, claim later.
                        self.workers[tid] = W::StealWrite {
                            scan,
                            pending: self.t2[m],
                        };
                    } else {
                        let idx = self.chunk_index(m, self.t2[m]);
                        self.claims[idx] += 1;
                        self.t2[m] += 1;
                    }
                } else {
                    self.workers[tid] = W::Steal { scan: scan + 1 };
                }
            }
            W::StealWrite { scan, pending } => {
                // BUG variant second half: claims against the stale read and
                // clobbers whatever the owner did in between.
                let idx = self.chunk_index(scan as usize, pending);
                self.claims[idx] += 1;
                self.t2[scan as usize] = pending + 1;
                self.workers[tid] = W::Steal { scan };
            }
            W::Done => {}
        }
    }

    fn invariant(&self) -> Result<(), String> {
        for (i, &c) in self.claims.iter().enumerate() {
            if c > 1 {
                return Err(format!("chunk {i} claimed {c} times"));
            }
        }
        Ok(())
    }

    fn is_done(&self) -> bool {
        self.workers.iter().all(|&w| w == W::Done)
    }

    fn final_check(&self) -> Result<(), String> {
        for (i, &c) in self.claims.iter().enumerate() {
            if c != 1 {
                return Err(format!("chunk {i} claimed {c} times (want exactly 1)"));
            }
        }
        Ok(())
    }
}

fn steal_model(racy: bool) -> StealModel {
    if cfg!(loom) {
        // Larger space for the dedicated loom CI job: a third worker with
        // no queue of its own becomes a pure stealer.
        StealModel::new(3, 2, 1, 2, racy)
    } else {
        StealModel::new(2, 2, 1, 2, racy)
    }
}

const STEAL_STATE_CAP: usize = 4_000_000;

#[test]
fn steal_claims_every_chunk_exactly_once_in_all_interleavings() {
    let report = explore(steal_model(false), STEAL_STATE_CAP).expect("two-tier steal model");
    assert!(report.finals >= 1, "no terminal schedule: {report:?}");
    assert!(
        report.states > 50,
        "state space suspiciously small: {report:?}"
    );
}

#[test]
fn explorer_catches_unsynchronized_sub_mca_steal() {
    let err = explore(steal_model(true), STEAL_STATE_CAP).unwrap_err();
    assert!(err.contains("claimed"), "wrong failure: {err}");
}

// ---------------------------------------------------------------------------
// Model 2: InflightGuard vs evict
// ---------------------------------------------------------------------------

/// A client running `execute_batch` against one resident operand.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Client {
    /// About to admit: check residency (+ bump inflight, if atomic).
    Admit,
    /// Racy-variant only: residency observed, inflight bump still pending
    /// (models re-acquiring the lock after an unlocked check).
    AdmitWrite,
    /// Executing with an `InflightGuard` held.
    Exec,
    DoneOk,
    DoneStale,
}

/// The evictor racing the clients.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Evictor {
    Start,
    /// Residency removed (inflight was zero).
    DoneEvicted,
    /// Surfaced `OperandBusy` (inflight was nonzero).
    DoneBusy,
    /// Surfaced `StaleOperand` (already gone).
    DoneStale,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct AdmissionModel {
    /// When set, admission checks residency and bumps inflight in two
    /// separate steps instead of one locked step.
    racy_admit: bool,
    resident: bool,
    inflight: u8,
    clients: Vec<Client>,
    evictor: Evictor,
}

impl AdmissionModel {
    fn new(clients: usize, racy: bool) -> AdmissionModel {
        AdmissionModel {
            racy_admit: racy,
            resident: true,
            inflight: 0,
            clients: vec![Client::Admit; clients],
            evictor: Evictor::Start,
        }
    }

    fn evictor_tid(&self) -> usize {
        self.clients.len()
    }
}

impl Model for AdmissionModel {
    fn runnable(&self) -> Vec<usize> {
        let mut out: Vec<usize> = (0..self.clients.len())
            .filter(|&t| {
                !matches!(self.clients[t], Client::DoneOk | Client::DoneStale)
            })
            .collect();
        if self.evictor == Evictor::Start {
            out.push(self.evictor_tid());
        }
        out
    }

    fn step(&mut self, tid: usize) {
        if tid == self.evictor_tid() {
            // Evict runs entirely under the structural lock: one step.
            self.evictor = if !self.resident {
                Evictor::DoneStale
            } else if self.inflight > 0 {
                Evictor::DoneBusy
            } else {
                self.resident = false;
                Evictor::DoneEvicted
            };
            return;
        }
        match self.clients[tid] {
            Client::Admit => {
                if !self.resident {
                    self.clients[tid] = Client::DoneStale;
                } else if self.racy_admit {
                    // BUG variant: residency observed, lock released before
                    // the inflight bump.
                    self.clients[tid] = Client::AdmitWrite;
                } else {
                    // Faithful: check + bump under one structural-lock step.
                    self.inflight += 1;
                    self.clients[tid] = Client::Exec;
                }
            }
            Client::AdmitWrite => {
                self.inflight += 1;
                self.clients[tid] = Client::Exec;
            }
            Client::Exec => {
                // Guard drop releases the inflight count: one step.
                self.inflight -= 1;
                self.clients[tid] = Client::DoneOk;
            }
            Client::DoneOk | Client::DoneStale => {}
        }
    }

    fn invariant(&self) -> Result<(), String> {
        let executing = self
            .clients
            .iter()
            .filter(|&&c| c == Client::Exec)
            .count() as u8;
        if executing > 0 && !self.resident {
            return Err("torn residency: a batch is executing on an evicted operand".into());
        }
        if self.inflight != executing {
            return Err(format!(
                "inflight count {} disagrees with {executing} executing batches",
                self.inflight
            ));
        }
        Ok(())
    }

    fn is_done(&self) -> bool {
        self.evictor != Evictor::Start
            && self
                .clients
                .iter()
                .all(|&c| matches!(c, Client::DoneOk | Client::DoneStale))
    }

    fn final_check(&self) -> Result<(), String> {
        if self.inflight != 0 {
            return Err(format!("inflight count leaked: {}", self.inflight));
        }
        match self.evictor {
            Evictor::DoneEvicted if self.resident => {
                Err("evict reported success but residency survived".into())
            }
            Evictor::DoneBusy if !self.resident => {
                Err("evict reported OperandBusy but removed the residency".into())
            }
            _ => Ok(()),
        }
    }
}

fn admission_model(racy: bool) -> AdmissionModel {
    AdmissionModel::new(if cfg!(loom) { 2 } else { 1 }, racy)
}

const ADMIT_STATE_CAP: usize = 1_000_000;

#[test]
fn admission_never_tears_residency_in_any_interleaving() {
    let report = explore(admission_model(false), ADMIT_STATE_CAP).expect("admission model");
    assert!(report.finals >= 2, "expected multiple outcomes: {report:?}");
}

#[test]
fn explorer_catches_check_then_admit_race() {
    let err = explore(admission_model(true), ADMIT_STATE_CAP).unwrap_err();
    assert!(err.contains("torn residency"), "wrong failure: {err}");
}

#[test]
fn busy_eviction_surfaces_operand_busy_not_a_torn_residency() {
    // Directed schedule: admit first, then evict mid-flight.
    let mut m = admission_model(false);
    m.step(0); // client 0 admits: inflight = 1
    assert_eq!(m.clients[0], Client::Exec);
    let evictor = m.evictor_tid();
    m.step(evictor);
    assert_eq!(m.evictor, Evictor::DoneBusy);
    assert!(m.resident, "busy eviction must leave the residency intact");
    m.invariant().expect("mid-flight state is consistent");
}

#[test]
fn evicting_idle_then_admitting_surfaces_stale_not_torn() {
    let mut m = admission_model(false);
    let evictor = m.evictor_tid();
    m.step(evictor); // inflight == 0: eviction succeeds
    assert_eq!(m.evictor, Evictor::DoneEvicted);
    m.step(0); // late client must see StaleOperand, never execute
    assert_eq!(m.clients[0], Client::DoneStale);
    m.invariant().expect("post-evict state is consistent");
}
