//! Concurrency models for the execution plane, checked over **every**
//! interleaving by the exhaustive explorer in `meliso::testing::sched`
//! (the repo's vendored loom stand-in — see that module's docs for why
//! loom itself is not in the build closure).
//!
//! Three designs get modeled, each in two variants:
//!
//! 1. **Two-tier steal cursors** (`plane/shard.rs`): workers claim MCAs
//!    from per-queue tier-1 cursors, drain each MCA's chunks through a
//!    tier-2 cursor, then sub-MCA-steal chunks from busy MCAs.  The
//!    faithful model (every cursor claim is one `fetch_add` step) must
//!    show every chunk claimed **exactly once** in every schedule.  A
//!    deliberately broken variant splits the stealer's claim into a read
//!    step and a write step; the explorer must find the double-claim,
//!    proving the harness actually has teeth.
//!
//! 2. **`InflightGuard` vs `evict`** (`plane/handle.rs`): admission
//!    checks residency and bumps the inflight count under one structural
//!    lock; evict checks the inflight count under the same lock and
//!    surfaces `OperandBusy` instead of removing a residency that a
//!    batch is using.  The faithful model never executes against an
//!    evicted residency; the broken variant (check residency, release
//!    the lock, then bump inflight) must be caught as a torn residency.
//!
//! 3. **The serve coalescer's gather window** (`serve/coalesce.rs`):
//!    producers submit solve requests, a single dispatcher gathers a
//!    window and demuxes one completion per request.  The faithful model
//!    (the window hand-off is one atomic step — the mpsc channel in the
//!    real code) must complete every submitted request **exactly once**
//!    in every schedule.  A deliberately racy variant snapshots the
//!    window and clears it in two separate steps; the explorer must find
//!    the schedule where a submission lands in between and is lost.
//!
//! The tests always run; `RUSTFLAGS="--cfg loom"` (the CI static-analysis
//! job) scales the thread counts up for a larger state space.
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]

use meliso::testing::sched::{explore, Model};

// ---------------------------------------------------------------------------
// Model 1: two-tier steal cursors
// ---------------------------------------------------------------------------

/// One worker's control state.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum W {
    /// Claiming an MCA from queue `(tid + scan) % queues` (tier 1).
    Scan { scan: u8 },
    /// Draining chunks of an exclusively claimed MCA (tier 2, owner).
    Drain { mca: u8 },
    /// Sub-MCA stealing: scanning MCA `scan` for leftover chunks.
    Steal { scan: u8 },
    /// Racy-variant only: holds a stale tier-2 cursor read, write pending.
    StealWrite { scan: u8, pending: u8 },
    Done,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct StealModel {
    /// When set, stealers claim with a read step then a write step
    /// instead of one atomic step — the bug the real design excludes.
    racy_steal: bool,
    queues: u8,
    mcas_per_queue: u8,
    /// Chunks per MCA.
    chunks: u8,
    /// Tier-1 cursor per queue (next unclaimed MCA offset).
    t1: Vec<u8>,
    /// Tier-2 cursor per MCA (next unclaimed chunk).
    t2: Vec<u8>,
    /// Claim count per chunk, indexed `mca * chunks + chunk`.
    claims: Vec<u8>,
    workers: Vec<W>,
}

impl StealModel {
    fn new(workers: usize, queues: u8, mcas_per_queue: u8, chunks: u8, racy: bool) -> StealModel {
        let mcas = (queues * mcas_per_queue) as usize;
        StealModel {
            racy_steal: racy,
            queues,
            mcas_per_queue,
            chunks,
            t1: vec![0; queues as usize],
            t2: vec![0; mcas],
            claims: vec![0; mcas * chunks as usize],
            workers: vec![W::Scan { scan: 0 }; workers],
        }
    }

    fn chunk_index(&self, mca: usize, chunk: u8) -> usize {
        mca * self.chunks as usize + chunk as usize
    }
}

impl Model for StealModel {
    fn runnable(&self) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|&t| self.workers[t] != W::Done)
            .collect()
    }

    fn step(&mut self, tid: usize) {
        match self.workers[tid] {
            W::Scan { scan } => {
                let q = ((tid as u8) + scan) % self.queues;
                if self.t1[q as usize] < self.mcas_per_queue {
                    // Tier-1 claim is a fetch_add: one step.
                    let mca = q * self.mcas_per_queue + self.t1[q as usize];
                    self.t1[q as usize] += 1;
                    self.workers[tid] = W::Drain { mca };
                } else if scan + 1 < self.queues {
                    self.workers[tid] = W::Scan { scan: scan + 1 };
                } else {
                    // Every queue exhausted: fall through to sub-MCA steal.
                    self.workers[tid] = W::Steal { scan: 0 };
                }
            }
            W::Drain { mca } => {
                let m = mca as usize;
                if self.t2[m] < self.chunks {
                    // Owner's tier-2 claim is a fetch_add: one step.
                    let idx = self.chunk_index(m, self.t2[m]);
                    self.claims[idx] += 1;
                    self.t2[m] += 1;
                } else {
                    self.workers[tid] = W::Scan { scan: 0 };
                }
            }
            W::Steal { scan } => {
                let m = scan as usize;
                if m >= self.t2.len() {
                    self.workers[tid] = W::Done;
                } else if self.t2[m] < self.chunks {
                    if self.racy_steal {
                        // BUG variant: read the cursor now, claim later.
                        self.workers[tid] = W::StealWrite {
                            scan,
                            pending: self.t2[m],
                        };
                    } else {
                        let idx = self.chunk_index(m, self.t2[m]);
                        self.claims[idx] += 1;
                        self.t2[m] += 1;
                    }
                } else {
                    self.workers[tid] = W::Steal { scan: scan + 1 };
                }
            }
            W::StealWrite { scan, pending } => {
                // BUG variant second half: claims against the stale read and
                // clobbers whatever the owner did in between.
                let idx = self.chunk_index(scan as usize, pending);
                self.claims[idx] += 1;
                self.t2[scan as usize] = pending + 1;
                self.workers[tid] = W::Steal { scan };
            }
            W::Done => {}
        }
    }

    fn invariant(&self) -> Result<(), String> {
        for (i, &c) in self.claims.iter().enumerate() {
            if c > 1 {
                return Err(format!("chunk {i} claimed {c} times"));
            }
        }
        Ok(())
    }

    fn is_done(&self) -> bool {
        self.workers.iter().all(|&w| w == W::Done)
    }

    fn final_check(&self) -> Result<(), String> {
        for (i, &c) in self.claims.iter().enumerate() {
            if c != 1 {
                return Err(format!("chunk {i} claimed {c} times (want exactly 1)"));
            }
        }
        Ok(())
    }
}

fn steal_model(racy: bool) -> StealModel {
    if cfg!(loom) {
        // Larger space for the dedicated loom CI job: a third worker with
        // no queue of its own becomes a pure stealer.
        StealModel::new(3, 2, 1, 2, racy)
    } else {
        StealModel::new(2, 2, 1, 2, racy)
    }
}

const STEAL_STATE_CAP: usize = 4_000_000;

#[test]
fn steal_claims_every_chunk_exactly_once_in_all_interleavings() {
    let report = explore(steal_model(false), STEAL_STATE_CAP).expect("two-tier steal model");
    assert!(report.finals >= 1, "no terminal schedule: {report:?}");
    assert!(
        report.states > 50,
        "state space suspiciously small: {report:?}"
    );
}

#[test]
fn explorer_catches_unsynchronized_sub_mca_steal() {
    let err = explore(steal_model(true), STEAL_STATE_CAP).unwrap_err();
    assert!(err.contains("claimed"), "wrong failure: {err}");
}

// ---------------------------------------------------------------------------
// Model 2: InflightGuard vs evict
// ---------------------------------------------------------------------------

/// A client running `execute_batch` against one resident operand.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Client {
    /// About to admit: check residency (+ bump inflight, if atomic).
    Admit,
    /// Racy-variant only: residency observed, inflight bump still pending
    /// (models re-acquiring the lock after an unlocked check).
    AdmitWrite,
    /// Executing with an `InflightGuard` held.
    Exec,
    DoneOk,
    DoneStale,
}

/// The evictor racing the clients.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Evictor {
    Start,
    /// Residency removed (inflight was zero).
    DoneEvicted,
    /// Surfaced `OperandBusy` (inflight was nonzero).
    DoneBusy,
    /// Surfaced `StaleOperand` (already gone).
    DoneStale,
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct AdmissionModel {
    /// When set, admission checks residency and bumps inflight in two
    /// separate steps instead of one locked step.
    racy_admit: bool,
    resident: bool,
    inflight: u8,
    clients: Vec<Client>,
    evictor: Evictor,
}

impl AdmissionModel {
    fn new(clients: usize, racy: bool) -> AdmissionModel {
        AdmissionModel {
            racy_admit: racy,
            resident: true,
            inflight: 0,
            clients: vec![Client::Admit; clients],
            evictor: Evictor::Start,
        }
    }

    fn evictor_tid(&self) -> usize {
        self.clients.len()
    }
}

impl Model for AdmissionModel {
    fn runnable(&self) -> Vec<usize> {
        let mut out: Vec<usize> = (0..self.clients.len())
            .filter(|&t| {
                !matches!(self.clients[t], Client::DoneOk | Client::DoneStale)
            })
            .collect();
        if self.evictor == Evictor::Start {
            out.push(self.evictor_tid());
        }
        out
    }

    fn step(&mut self, tid: usize) {
        if tid == self.evictor_tid() {
            // Evict runs entirely under the structural lock: one step.
            self.evictor = if !self.resident {
                Evictor::DoneStale
            } else if self.inflight > 0 {
                Evictor::DoneBusy
            } else {
                self.resident = false;
                Evictor::DoneEvicted
            };
            return;
        }
        match self.clients[tid] {
            Client::Admit => {
                if !self.resident {
                    self.clients[tid] = Client::DoneStale;
                } else if self.racy_admit {
                    // BUG variant: residency observed, lock released before
                    // the inflight bump.
                    self.clients[tid] = Client::AdmitWrite;
                } else {
                    // Faithful: check + bump under one structural-lock step.
                    self.inflight += 1;
                    self.clients[tid] = Client::Exec;
                }
            }
            Client::AdmitWrite => {
                self.inflight += 1;
                self.clients[tid] = Client::Exec;
            }
            Client::Exec => {
                // Guard drop releases the inflight count: one step.
                self.inflight -= 1;
                self.clients[tid] = Client::DoneOk;
            }
            Client::DoneOk | Client::DoneStale => {}
        }
    }

    fn invariant(&self) -> Result<(), String> {
        let executing = self
            .clients
            .iter()
            .filter(|&&c| c == Client::Exec)
            .count() as u8;
        if executing > 0 && !self.resident {
            return Err("torn residency: a batch is executing on an evicted operand".into());
        }
        if self.inflight != executing {
            return Err(format!(
                "inflight count {} disagrees with {executing} executing batches",
                self.inflight
            ));
        }
        Ok(())
    }

    fn is_done(&self) -> bool {
        self.evictor != Evictor::Start
            && self
                .clients
                .iter()
                .all(|&c| matches!(c, Client::DoneOk | Client::DoneStale))
    }

    fn final_check(&self) -> Result<(), String> {
        if self.inflight != 0 {
            return Err(format!("inflight count leaked: {}", self.inflight));
        }
        match self.evictor {
            Evictor::DoneEvicted if self.resident => {
                Err("evict reported success but residency survived".into())
            }
            Evictor::DoneBusy if !self.resident => {
                Err("evict reported OperandBusy but removed the residency".into())
            }
            _ => Ok(()),
        }
    }
}

fn admission_model(racy: bool) -> AdmissionModel {
    AdmissionModel::new(if cfg!(loom) { 2 } else { 1 }, racy)
}

const ADMIT_STATE_CAP: usize = 1_000_000;

#[test]
fn admission_never_tears_residency_in_any_interleaving() {
    let report = explore(admission_model(false), ADMIT_STATE_CAP).expect("admission model");
    assert!(report.finals >= 2, "expected multiple outcomes: {report:?}");
}

#[test]
fn explorer_catches_check_then_admit_race() {
    let err = explore(admission_model(true), ADMIT_STATE_CAP).unwrap_err();
    assert!(err.contains("torn residency"), "wrong failure: {err}");
}

#[test]
fn busy_eviction_surfaces_operand_busy_not_a_torn_residency() {
    // Directed schedule: admit first, then evict mid-flight.
    let mut m = admission_model(false);
    m.step(0); // client 0 admits: inflight = 1
    assert_eq!(m.clients[0], Client::Exec);
    let evictor = m.evictor_tid();
    m.step(evictor);
    assert_eq!(m.evictor, Evictor::DoneBusy);
    assert!(m.resident, "busy eviction must leave the residency intact");
    m.invariant().expect("mid-flight state is consistent");
}

#[test]
fn evicting_idle_then_admitting_surfaces_stale_not_torn() {
    let mut m = admission_model(false);
    let evictor = m.evictor_tid();
    m.step(evictor); // inflight == 0: eviction succeeds
    assert_eq!(m.evictor, Evictor::DoneEvicted);
    m.step(0); // late client must see StaleOperand, never execute
    assert_eq!(m.clients[0], Client::DoneStale);
    m.invariant().expect("post-evict state is consistent");
}

// ---------------------------------------------------------------------------
// Model 3: the serve coalescer's gather window
// ---------------------------------------------------------------------------

/// The gather-window dispatcher of the serving front door.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
enum Dispatcher {
    /// Waiting for submissions.
    Wait,
    /// Racy-variant only: window contents observed, queue clear pending.
    ReadDone { batch: Vec<u8> },
    /// Demuxing the gathered window, one completion per step.
    Exec { batch: Vec<u8> },
}

#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Debug)]
struct CoalesceModel {
    /// When set, the dispatcher snapshots the window and clears it in
    /// two separate steps instead of one atomic hand-off (the mpsc
    /// channel in the real coalescer) — a submission landing in between
    /// is wiped without ever being completed.
    racy_gather: bool,
    /// Pending submissions (the open gather window), in arrival order.
    queue: Vec<u8>,
    /// Which producers have submitted their one request.
    submitted: Vec<bool>,
    /// Completion count per request id (id == producer tid).
    completions: Vec<u8>,
    dispatcher: Dispatcher,
}

impl CoalesceModel {
    fn new(producers: usize, racy: bool) -> CoalesceModel {
        CoalesceModel {
            racy_gather: racy,
            queue: Vec::new(),
            submitted: vec![false; producers],
            completions: vec![0; producers],
            dispatcher: Dispatcher::Wait,
        }
    }

    fn dispatcher_tid(&self) -> usize {
        self.submitted.len()
    }
}

impl Model for CoalesceModel {
    fn runnable(&self) -> Vec<usize> {
        let mut out: Vec<usize> = (0..self.submitted.len())
            .filter(|&t| !self.submitted[t])
            .collect();
        let dispatcher_can_run = match &self.dispatcher {
            Dispatcher::Wait => !self.queue.is_empty(),
            Dispatcher::ReadDone { .. } | Dispatcher::Exec { .. } => true,
        };
        if dispatcher_can_run {
            out.push(self.dispatcher_tid());
        }
        out
    }

    fn step(&mut self, tid: usize) {
        if tid < self.submitted.len() {
            // One producer submission is one channel send: one step.
            self.queue.push(tid as u8);
            self.submitted[tid] = true;
            return;
        }
        self.dispatcher = match std::mem::replace(&mut self.dispatcher, Dispatcher::Wait) {
            Dispatcher::Wait => {
                if self.racy_gather {
                    // BUG variant: observe the window now, clear it later.
                    Dispatcher::ReadDone {
                        batch: self.queue.clone(),
                    }
                } else {
                    // Faithful: the channel hands the whole window over
                    // atomically — nothing can land "in between".
                    Dispatcher::Exec {
                        batch: std::mem::take(&mut self.queue),
                    }
                }
            }
            Dispatcher::ReadDone { batch } => {
                // BUG variant second half: wipes submissions that arrived
                // after the snapshot — they are never completed.
                self.queue.clear();
                Dispatcher::Exec { batch }
            }
            Dispatcher::Exec { mut batch } => {
                let id = batch.remove(0);
                self.completions[id as usize] += 1;
                if batch.is_empty() {
                    Dispatcher::Wait
                } else {
                    Dispatcher::Exec { batch }
                }
            }
        };
    }

    fn invariant(&self) -> Result<(), String> {
        for (i, &c) in self.completions.iter().enumerate() {
            if c > 1 {
                return Err(format!("request {i} completed {c} times"));
            }
        }
        Ok(())
    }

    fn is_done(&self) -> bool {
        self.submitted.iter().all(|&s| s)
            && self.queue.is_empty()
            && self.dispatcher == Dispatcher::Wait
    }

    fn final_check(&self) -> Result<(), String> {
        for (i, &c) in self.completions.iter().enumerate() {
            if c != 1 {
                return Err(format!("request {i} completed {c} times (want exactly once)"));
            }
        }
        Ok(())
    }
}

fn coalesce_model(racy: bool) -> CoalesceModel {
    CoalesceModel::new(if cfg!(loom) { 3 } else { 2 }, racy)
}

const COALESCE_STATE_CAP: usize = 1_000_000;

#[test]
fn coalescer_completes_every_request_exactly_once_in_all_interleavings() {
    let report =
        explore(coalesce_model(false), COALESCE_STATE_CAP).expect("coalescer window model");
    assert!(report.finals >= 1, "no terminal schedule: {report:?}");
    assert!(
        report.states > 10,
        "state space suspiciously small: {report:?}"
    );
}

#[test]
fn explorer_catches_torn_gather_window() {
    let err = explore(coalesce_model(true), COALESCE_STATE_CAP).unwrap_err();
    assert!(err.contains("completed"), "wrong failure: {err}");
}

#[test]
fn torn_window_loses_the_submission_that_raced_the_snapshot() {
    // Directed schedule for the racy variant: producer 0 submits, the
    // dispatcher snapshots the window, producer 1 submits, the clear
    // wipes it — request 1 is never completed.
    let mut m = CoalesceModel::new(2, true);
    let d = m.dispatcher_tid();
    m.step(0); // queue = [0]
    m.step(d); // snapshot [0], clear pending
    m.step(1); // queue = [0, 1]
    m.step(d); // clear: request 1 is gone
    assert!(m.queue.is_empty(), "clear left the window populated");
    m.step(d); // complete request 0
    assert!(m.is_done());
    let err = m.final_check().unwrap_err();
    assert!(err.contains("request 1 completed 0 times"), "{err}");
}

#[test]
fn atomic_window_handoff_completes_late_arrivals_in_the_next_window() {
    // The same schedule against the faithful model: the late submission
    // survives in the queue and is completed by the next window.
    let mut m = CoalesceModel::new(2, false);
    let d = m.dispatcher_tid();
    m.step(0); // queue = [0]
    m.step(d); // window [0] handed off atomically
    m.step(1); // queue = [1] — the next window's content
    m.step(d); // complete request 0
    m.step(d); // gather the next window: [1]
    m.step(d); // complete request 1
    assert!(m.is_done());
    m.final_check().expect("every request completed exactly once");
}
