//! Fault-injection battery for the serving front door.  Every scenario
//! runs under a hard wall-clock bound (the `fault_tolerance.rs` idiom):
//! a regression that turns a fault into a hang trips the bound instead
//! of wedging CI.
//!
//! * a shard panic mid-coalesced-window fans typed errors to **every**
//!   waiter, and the operand-cache plane rebuild restores service on the
//!   very next request — no re-upload, no restart;
//! * a client that disconnects mid-solve leaks nothing: the solve
//!   completes, its reply is discarded, the admission permit is
//!   released, in-flight returns to zero;
//! * an admission burst past the global budget rejects the excess with
//!   deterministic typed 503s and never deadlocks (held requests parked
//!   inside a [`GateBackend`] prove the budget was genuinely full).

use meliso::linalg::Vector;
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::serve::{ServeConfig, Server};
use meliso::testing::faults::{FaultBackend, GateBackend};
use meliso::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Hard bound on any single scenario (generous for slow CI runners).
const SCENARIO_TIMEOUT: Duration = Duration::from_secs(120);

/// Run `f` on a helper thread; fail instead of hanging if it stalls.
fn bounded<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::Builder::new()
        .name(format!("bounded-{name}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn scenario thread");
    match rx.recv_timeout(SCENARIO_TIMEOUT) {
        Ok(v) => v,
        Err(_) => panic!("scenario {name:?} hung past {SCENARIO_TIMEOUT:?}"),
    }
}

fn config() -> SystemConfig {
    SystemConfig::new(2, 2, 32)
}

fn opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_workers(2)
        .with_seed(11)
}

fn serve_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        http_threads: 8,
        ..ServeConfig::default()
    }
}

fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    client_id: &str,
    body: &[u8],
) -> (u16, String) {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(90))).unwrap();
    conn.set_write_timeout(Some(Duration::from_secs(90))).unwrap();
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: meliso-test\r\nX-Client-Id: {client_id}\r\n\
         Content-Length: {}\r\n\r\n",
        body.len()
    );
    conn.write_all(head.as_bytes()).unwrap();
    conn.write_all(body).unwrap();
    conn.flush().unwrap();
    let mut raw = String::new();
    conn.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("malformed response: {raw}"));
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn upload(addr: SocketAddr, client: &str, name: &str) -> String {
    let (status, resp) = http(
        addr,
        "POST",
        "/operands",
        client,
        format!("{{\"name\": \"{name}\"}}").as_bytes(),
    );
    assert_eq!(status, 200, "{resp}");
    Json::parse(&resp)
        .unwrap()
        .get("operand")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

fn solve_body(x: &Vector) -> String {
    let mut doc = Json::obj();
    doc.set(
        "x",
        Json::Arr(x.data().iter().map(|&v| Json::Num(v)).collect()),
    );
    doc.compact()
}

fn error_code(body: &str) -> String {
    Json::parse(body)
        .unwrap()
        .get("error")
        .unwrap()
        .get("code")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string()
}

#[test]
fn shard_panic_mid_window_errors_every_waiter_then_rebuild_restores_service() {
    bounded("shard-panic-rebuild", || {
        let backend = FaultBackend::panicking(NativeBackend::new());
        let fault = backend.handle();
        let solver = Meliso::with_backend(config(), opts(), Arc::new(backend));
        let server = Server::start(solver, serve_config()).unwrap();
        let addr = server.addr();
        // Programming never touches the backend, so the upload succeeds
        // with the fault disarmed and the panic fires inside a shard's
        // execute walk mid-coalesced-window.
        let handle = upload(addr, "victim", "spd64");
        fault.fail_next_reads(true);

        const WAITERS: usize = 4;
        let results: Vec<(u16, String)> = std::thread::scope(|s| {
            let joins: Vec<_> = (0..WAITERS)
                .map(|t| {
                    let handle = handle.clone();
                    s.spawn(move || {
                        let x = Vector::standard_normal(64, 900 + t as u64);
                        http(
                            addr,
                            "POST",
                            &format!("/operands/{handle}/solve"),
                            &format!("victim-{t}"),
                            solve_body(&x).as_bytes(),
                        )
                    })
                })
                .collect();
            joins.into_iter().map(|j| j.join().unwrap()).collect()
        });
        // Every waiter got a typed error — none hung, none got a partial
        // result, and the error taxonomy held (5xx, machine-readable).
        for (status, body) in &results {
            assert!(
                *status == 500 || *status == 503 || *status == 504,
                "expected a typed 5xx, got {status}: {body}"
            );
            let code = error_code(body);
            assert!(
                code == "internal" || code == "overloaded" || code == "timeout",
                "unexpected code {code}: {body}"
            );
        }

        // Disarm and solve again: the cache notices the failed plane,
        // rebuilds, re-programs the registered operand, and serves — the
        // client never re-uploaded anything.
        fault.fail_next_reads(false);
        let x = Vector::standard_normal(64, 990);
        let (status, resp) = http(
            addr,
            "POST",
            &format!("/operands/{handle}/solve"),
            "victim",
            solve_body(&x).as_bytes(),
        );
        assert_eq!(status, 200, "service did not recover: {resp}");
        assert_eq!(server.state().inflight(), 0);
        server.shutdown();
    });
}

#[test]
fn client_disconnect_mid_solve_leaks_nothing() {
    bounded("client-disconnect", || {
        let backend = GateBackend::new(NativeBackend::new());
        let gate = backend.handle();
        let solver = Meliso::with_backend(config(), opts(), Arc::new(backend));
        let server = Server::start(solver, serve_config()).unwrap();
        let addr = server.addr();
        let handle = upload(addr, "ghost", "spd64");

        // Hold the next solve inside the backend, then hang up on it.
        gate.close();
        {
            let mut conn = TcpStream::connect(addr).unwrap();
            let body = solve_body(&Vector::standard_normal(64, 40));
            let head = format!(
                "POST /operands/{handle}/solve HTTP/1.1\r\nHost: x\r\n\
                 X-Client-Id: ghost\r\nContent-Length: {}\r\n\r\n",
                body.len()
            );
            conn.write_all(head.as_bytes()).unwrap();
            conn.write_all(body.as_bytes()).unwrap();
            conn.flush().unwrap();
            // The request is demonstrably mid-solve: reads are parked at
            // the gate.  Now the client vanishes without reading.
            while gate.waiting() == 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
            assert_eq!(server.state().inflight(), 1);
        } // <- connection dropped here

        gate.open();
        // The orphaned solve completes, its reply is discarded, and the
        // admission permit is released: in-flight returns to zero.
        while server.state().inflight() != 0 {
            std::thread::sleep(Duration::from_millis(5));
        }
        // Nothing wedged: the next client is served, and the orphaned
        // solve really executed (it consumed solve index 0).
        let (status, resp) = http(
            addr,
            "POST",
            &format!("/operands/{handle}/solve"),
            "alive",
            solve_body(&Vector::standard_normal(64, 41)).as_bytes(),
        );
        assert_eq!(status, 200, "{resp}");
        let index = Json::parse(&resp)
            .unwrap()
            .get("solve_index")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(index, 1, "orphaned solve was dropped instead of completed");
        server.shutdown();
    });
}

#[test]
fn admission_burst_rejects_excess_deterministically_and_never_deadlocks() {
    bounded("admission-burst", || {
        let backend = GateBackend::new(NativeBackend::new());
        let gate = backend.handle();
        let solver = Meliso::with_backend(config(), opts(), Arc::new(backend));
        let cfg = ServeConfig {
            max_inflight: 2,
            max_inflight_per_client: 1,
            ..serve_config()
        };
        let server = Server::start(solver, cfg).unwrap();
        let addr = server.addr();
        let handle = upload(addr, "seed", "spd64");

        // Park enough solves at the gate to fill the global budget, so
        // every burst probe below sees a deterministically-full server.
        gate.close();
        std::thread::scope(|s| {
            let holders: Vec<_> = (0..2)
                .map(|t| {
                    let handle = handle.clone();
                    s.spawn(move || {
                        let x = Vector::standard_normal(64, 60 + t as u64);
                        http(
                            addr,
                            "POST",
                            &format!("/operands/{handle}/solve"),
                            &format!("holder-{t}"),
                            solve_body(&x).as_bytes(),
                        )
                    })
                })
                .collect();
            // Both holders admitted (permits held; at least one is
            // provably parked inside the backend) — the budget is full.
            while server.state().inflight() != 2 {
                std::thread::sleep(Duration::from_millis(5));
            }
            while gate.waiting() == 0 {
                std::thread::sleep(Duration::from_millis(5));
            }
            // Every probe in the burst is refused with the same typed
            // 503 — no probe is queued, delayed, or deadlocked.
            for t in 0..4u64 {
                let (status, body) = http(
                    addr,
                    "POST",
                    &format!("/operands/{handle}/solve"),
                    &format!("burst-{t}"),
                    solve_body(&Vector::standard_normal(64, 70 + t)).as_bytes(),
                );
                assert_eq!(status, 503, "{body}");
                assert_eq!(error_code(&body), "overloaded", "{body}");
            }
            gate.open();
            for h in holders {
                let (status, body) = h.join().unwrap();
                assert_eq!(status, 200, "held solve failed after release: {body}");
            }
        });
        while server.state().inflight() != 0 {
            std::thread::sleep(Duration::from_millis(5));
        }

        // No deadlock and no latch: with the gate open the same burst
        // shape is served in full.
        for t in 0..3 {
            let (status, resp) = http(
                addr,
                "POST",
                &format!("/operands/{handle}/solve"),
                &format!("after-{t}"),
                solve_body(&Vector::standard_normal(64, 80 + t)).as_bytes(),
            );
            assert_eq!(status, 200, "{resp}");
        }
        server.shutdown();
    });
}
