//! Integration tests: the full solve pipeline over the native backend
//! (device sim -> write-verify -> EC -> virtualization -> coordinator ->
//! metrics), exercising the paper's experiment configurations end to end.

use meliso::device::materials::Material;
use meliso::matrices::{registry, DenseSource};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use std::sync::Arc;

fn native_solver(config: SystemConfig, opts: SolveOptions) -> Meliso {
    Meliso::with_backend(config, opts, Arc::new(NativeBackend::new()))
}

#[test]
fn table1_shape_taox_ec_beats_epiram_raw() {
    let source = registry::build("bcsstk02").unwrap();
    let x = Vector::standard_normal(66, 1);
    let cfg = SystemConfig::single_mca(128);

    let epiram = native_solver(
        cfg,
        SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_ec(false),
    );
    let taox = native_solver(
        cfg,
        SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_ec(true)
            .with_wv_iters(5),
    );
    let reps = 5;
    let e: f64 = epiram
        .replicate(source.as_ref(), &x, reps)
        .unwrap()
        .iter()
        .map(|r| r.rel_err_l2)
        .sum::<f64>()
        / reps as f64;
    let t_reports = taox.replicate(source.as_ref(), &x, reps).unwrap();
    let t: f64 = t_reports.iter().map(|r| r.rel_err_l2).sum::<f64>() / reps as f64;
    assert!(
        t <= e * 1.2,
        "TaOx+EC ({t:.4}) should match/beat EpiRAM raw ({e:.4})"
    );
    // Energy/latency advantage (>=2.5 orders energy, >=1.5 orders latency).
    let e_rep = epiram.solve_source(source.as_ref(), &x).unwrap();
    let t_rep = &t_reports[0];
    assert!(e_rep.ew_mean / t_rep.ew_mean > 300.0);
    assert!(e_rep.lw_mean / t_rep.lw_mean > 30.0);
}

#[test]
fn fig2_shape_error_decreases_with_k_then_floors() {
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 2);
    let cfg = SystemConfig::single_mca(128);
    let err_at_k = |k: usize| {
        let solver = native_solver(
            cfg,
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_ec(false)
                .with_wv_iters(k),
        );
        let reps = 6;
        solver
            .replicate(source.as_ref(), &x, reps)
            .unwrap()
            .iter()
            .map(|r| r.rel_err_l2)
            .sum::<f64>()
            / reps as f64
    };
    let e0 = err_at_k(0);
    let e2 = err_at_k(2);
    let e10 = err_at_k(10);
    assert!(e2 < e0 * 0.7, "k=2 ({e2:.4}) should improve on k=0 ({e0:.4})");
    // Stabilized: k=10 within a modest factor of k=2 (TaOx floors early).
    assert!(e10 < e2 * 1.5 && e10 > e2 * 0.2, "e2={e2:.4} e10={e10:.4}");
}

#[test]
fn fig4_shape_small_cells_cost_more_energy() {
    let source = registry::build("add32").unwrap();
    let x = Vector::standard_normal(source.ncols(), 3);
    let run = |cell: usize| {
        let solver = native_solver(
            SystemConfig::tiles_8x8(cell),
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_ec(true)
                .with_wv_iters(2)
                .with_workers(4),
        );
        solver.solve_source(source.as_ref(), &x).unwrap()
    };
    let small = run(128);
    let large = run(1024);
    // Accuracy flat across cell sizes…
    assert!(
        small.rel_err_l2 < 0.1 && large.rel_err_l2 < 0.1,
        "small {} large {}",
        small.rel_err_l2,
        large.rel_err_l2
    );
    // …but small cells pay virtualization: strictly more chunks and more
    // mean per-MCA write latency.
    assert!(small.chunks_total > large.chunks_total);
    assert!(small.row_reassignments > large.row_reassignments);
}

#[test]
fn fig5_shape_larger_problems_grow_latency() {
    let x1 = Vector::standard_normal(66, 4);
    let small = native_solver(
        SystemConfig::tiles_8x8(1024),
        SolveOptions::default().with_device(Material::TaOxHfOx),
    )
    .solve_source(registry::build("bcsstk02").unwrap().as_ref(), &x1)
    .unwrap();

    let big_src = registry::build("add32").unwrap();
    let x2 = Vector::standard_normal(big_src.ncols(), 5);
    let big = native_solver(
        SystemConfig::tiles_8x8(1024),
        SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_workers(4),
    )
    .solve_source(big_src.as_ref(), &x2)
    .unwrap();
    assert!(big.ew_mean > small.ew_mean);
    assert!(big.lw_max >= small.lw_max);
}

#[test]
fn aggregation_sums_column_chunks_exactly() {
    // With a noise-free path impossible, verify aggregation algebra via a
    // near-perfect device (EpiRAM, EC, deep verify) on a block-structured
    // operand spanning multiple column chunks.
    let n = 96; // 3x3 chunks of 32
    let a = Matrix::standard_normal(n, n, 6);
    let src = DenseSource::new(a.clone());
    let x = Vector::standard_normal(n, 7);
    let solver = native_solver(
        SystemConfig::new(2, 2, 32),
        SolveOptions::default()
            .with_device(Material::EpiRam)
            .with_ec(true)
            .with_wv_iters(8)
            .with_workers(2),
    );
    let report = solver.solve_source(&src, &x).unwrap();
    let b = a.matvec(&x);
    // Each output element is the sum of 3 chunk partials; error stays at
    // the device floor, proving no double counting / missing chunks.
    assert!(report.rel_err_l2 < 0.05, "{}", report.rel_err_l2);
    assert_eq!(report.y.len(), n);
    assert!((report.y.get(0) - b.get(0)).abs() < 0.2 * b.norm_inf());
}

#[test]
fn json_report_is_parseable() {
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 8);
    let solver = native_solver(SystemConfig::single_mca(128), SolveOptions::default());
    let report = solver.solve_source(source.as_ref(), &x).unwrap();
    let text = report.to_json().pretty();
    let parsed = meliso::util::json::Json::parse(&text).unwrap();
    assert!(parsed.get("rel_err_l2").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn denoise_ablation_modes_ordered() {
    // On a well-conditioned operand the in-memory denoiser (λ=1e-12) must
    // not be dramatically worse than digital; EC off-mode (first-order
    // only) is close to both.
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 9);
    let cfg = SystemConfig::single_mca(128);
    let err = |mode| {
        let solver = native_solver(
            cfg,
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_denoise(mode)
                .with_wv_iters(2),
        );
        let reps = 5;
        solver
            .replicate(source.as_ref(), &x, reps)
            .unwrap()
            .iter()
            .map(|r| r.rel_err_l2)
            .sum::<f64>()
            / reps as f64
    };
    let inmem = err(DenoiseMode::InMemory);
    let digital = err(DenoiseMode::Digital);
    let off = err(DenoiseMode::Off);
    assert!(inmem < digital * 3.0, "inmem {inmem:.4} vs digital {digital:.4}");
    assert!(off < inmem * 3.0, "off {off:.4} vs inmem {inmem:.4}");
}

#[test]
fn config_roundtrip_through_solver() {
    let (sys, opts) = meliso::config::from_toml(
        r#"
        [system]
        tile_rows = 1
        tile_cols = 1
        cell_size = 64

        [solve]
        device = "epiram"
        ec = true
        wv_iters = 1
        backend = "native"
        workers = 1
        "#,
    )
    .unwrap();
    let a = Matrix::standard_normal(64, 64, 10);
    let x = Vector::standard_normal(64, 11);
    let solver = native_solver(sys, opts);
    let report = solver.solve(&a, &x).unwrap();
    assert!(report.rel_err_l2 < 0.1);
}
