//! Concurrent-admission regression suite for the shared-handle execution
//! plane: many client threads, many resident operands, one shard pool.
//!
//! Three invariants the `PlaneHandle` redesign must uphold:
//!
//! * **bit-identity under multi-tenancy** — N threads solving M operands
//!   concurrently on one plane produce exactly the results of M dedicated
//!   planes (execution noise is counter-based per `(operand, solve,
//!   chunk)`, so scheduling cannot leak into the numerics);
//! * **no deadlock under faults** — a shard panic mid-batch with several
//!   concurrent clients surfaces as a clean typed error on every thread,
//!   within a hard wall-clock bound, never a hang;
//! * **work-stealing determinism** — irregular operands unbalance the
//!   per-shard queues and trigger stealing; the steal order is
//!   timing-dependent, the results must not be;
//! * **materialization-path determinism** — shard-side tile extraction
//!   from chunk descriptors (`program_shared` / `execute_once_shared`)
//!   must be bit-identical to leader extraction, one-shot and resident;
//! * **sub-MCA steal determinism** — when every occupied chunk lives on
//!   one MCA, whole-MCA stealing cannot help and progress at high shard
//!   counts requires thieves inside a single MCA's chunk grid; execution
//!   noise is keyed by `(operand, solve, chunk)` counters, so even that
//!   interleaving must be invisible in the results.

use meliso::matrices::{generators, BandedSource, DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::testing::faults::FaultBackend;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

const SCENARIO_TIMEOUT: Duration = Duration::from_secs(120);

/// Run `f` on a helper thread and fail the test if it does not finish in
/// [`SCENARIO_TIMEOUT`] — a lost wakeup or admission deadlock trips this
/// bound instead of wedging the whole test run.
fn bounded<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::Builder::new()
        .name(format!("bounded-{name}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn scenario thread");
    match rx.recv_timeout(SCENARIO_TIMEOUT) {
        Ok(v) => v,
        Err(_) => panic!("scenario {name:?} hung past {SCENARIO_TIMEOUT:?} (deadlock regression)"),
    }
}

fn native() -> meliso::runtime::Backend {
    Arc::new(NativeBackend::new())
}

fn config() -> SystemConfig {
    SystemConfig::new(2, 2, 32)
}

fn opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_seed(0x5EED)
        .with_workers(3)
}

/// Mixed tenant set: dense, banded (regular sparsity) and power-law CSR
/// (irregular sparsity, the work-stealing trigger).
fn tenants(n: usize) -> Vec<Arc<dyn MatrixSource>> {
    vec![
        Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 0xA1))),
        Arc::new(BandedSource::new(n, 5, 1.0, 8.0, 0.25, 0xA2)),
        Arc::new(generators::power_law_csr(n, 3, 4.0, 50.0, 0.2, 0xA3)),
        Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 0xA4))),
    ]
}

fn inputs(srcs: &[Arc<dyn MatrixSource>], solves: usize) -> Vec<Vec<Vector>> {
    srcs.iter()
        .enumerate()
        .map(|(m, s)| {
            (0..solves)
                .map(|k| Vector::standard_normal(s.ncols(), 0xB0 + (m * 100 + k) as u64))
                .collect()
        })
        .collect()
}

#[test]
fn concurrent_tenants_match_dedicated_planes_bit_exact() {
    bounded("concurrent-bit-identity", || {
        let srcs = tenants(96);
        let xs = inputs(&srcs, 3);

        // References: each operand on its own dedicated plane, solved
        // sequentially.
        let dedicated: Vec<Vec<Vector>> = srcs
            .iter()
            .zip(&xs)
            .map(|(s, stream)| {
                let plane = PlaneHandle::build(s.as_ref(), &config(), &opts(), native()).unwrap();
                let (id, _) = plane.program(s.as_ref()).unwrap();
                stream
                    .iter()
                    .map(|x| {
                        plane
                            .execute_batch(id, std::slice::from_ref(x))
                            .unwrap()
                            .solves
                            .remove(0)
                            .y
                    })
                    .collect()
            })
            .collect();

        // One shared plane, one client thread per operand, all solving at
        // once through clones of the same handle.
        let plane =
            PlaneHandle::build(srcs[0].as_ref(), &config(), &opts(), native()).unwrap();
        let ids: Vec<OperandId> = srcs
            .iter()
            .map(|s| plane.program(s.as_ref()).unwrap().0)
            .collect();
        let shared: Vec<Vec<Vector>> = std::thread::scope(|scope| {
            let handles: Vec<_> = srcs
                .iter()
                .enumerate()
                .map(|(m, _)| {
                    let plane = plane.clone();
                    let id = ids[m];
                    let stream = &xs[m];
                    scope.spawn(move || {
                        stream
                            .iter()
                            .map(|x| {
                                plane
                                    .execute_batch(id, std::slice::from_ref(x))
                                    .unwrap()
                                    .solves
                                    .remove(0)
                                    .y
                            })
                            .collect::<Vec<Vector>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("client thread"))
                .collect()
        });
        assert_eq!(plane.resident_operands(), srcs.len());
        for (m, (ded, shr)) in dedicated.iter().zip(&shared).enumerate() {
            assert_eq!(ded, shr, "operand {m} diverged under concurrent multi-tenancy");
        }
    });
}

#[test]
fn shard_panic_mid_concurrent_batches_never_deadlocks() {
    bounded("concurrent-shard-panic", || {
        let srcs = tenants(96);
        let xs = inputs(&srcs, 2);
        let backend = FaultBackend::panicking(NativeBackend::new());
        let fault = backend.handle();
        let plane =
            PlaneHandle::build(srcs[0].as_ref(), &config(), &opts(), Arc::new(backend)).unwrap();
        let ids: Vec<OperandId> = srcs
            .iter()
            .map(|s| plane.program(s.as_ref()).unwrap().0)
            .collect();
        // Arm the fault, then let every client fire at once: some batches
        // die on the panicking shard, the rest on the poisoned plane.
        // Every thread must get an error back — no hang, no lost client.
        fault.fail_next_reads(true);
        let errors: Vec<PlaneError> = std::thread::scope(|scope| {
            let handles: Vec<_> = srcs
                .iter()
                .enumerate()
                .map(|(m, _)| {
                    let plane = plane.clone();
                    let id = ids[m];
                    let stream = &xs[m];
                    scope.spawn(move || {
                        let mut errs = Vec::new();
                        for x in stream {
                            if let Err(e) = plane.execute_batch(id, std::slice::from_ref(x)) {
                                errs.push(e);
                            }
                        }
                        errs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread"))
                .collect()
        });
        assert!(!errors.is_empty(), "armed fault produced no errors");
        for e in &errors {
            assert!(
                matches!(e, PlaneError::ShardDead(_) | PlaneError::Failed(_)),
                "{e:?}"
            );
        }
        // The plane is poisoned: later calls fail fast with the root cause.
        assert!(plane.failure().is_some());
        fault.fail_next_reads(false);
        let err = plane
            .execute_batch(ids[0], std::slice::from_ref(&xs[0][0]))
            .unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
    });
}

#[test]
fn work_stealing_is_invisible_in_results() {
    bounded("steal-determinism", || {
        // A power-law CSR operand concentrates occupied chunks on a few
        // block rows, leaving some shard queues long and others empty —
        // exactly the imbalance batch workers steal across.  The steal
        // schedule is timing-dependent and differs run to run; the solve
        // must not.
        let src = generators::power_law_csr(160, 4, 4.0, 60.0, 0.25, 0xC1);
        let xs: Vec<Vector> = (0..3)
            .map(|k| Vector::standard_normal(src.ncols(), 0xC2 + k))
            .collect();
        let run = |workers: usize, placement: Placement| {
            let o = opts().with_workers(workers).with_placement(placement);
            let plane = PlaneHandle::build(&src, &config(), &o, native()).unwrap();
            let (id, _) = plane.program(&src).unwrap();
            // Two rounds: the second round gives the timing-aware policy
            // measured chunk times to redistribute by.
            (0..2)
                .map(|_| {
                    plane
                        .execute_batch(id, &xs)
                        .unwrap()
                        .solves
                        .into_iter()
                        .map(|s| s.y)
                        .collect::<Vec<Vector>>()
                })
                .collect::<Vec<_>>()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 3, 4] {
            for placement in [
                Placement::RoundRobin,
                Placement::LoadBalanced,
                Placement::SparsityAware,
                Placement::TimingAware,
            ] {
                // Repeat each configuration so at least some runs take
                // different steal schedules.
                for rep in 0..2 {
                    let got = run(workers, placement);
                    assert_eq!(
                        reference,
                        got,
                        "{workers} workers, {} (rep {rep}) diverged",
                        placement.name()
                    );
                }
            }
        }
    });
}

#[test]
fn descriptor_path_matches_leader_extraction_bit_exact() {
    bounded("descriptor-bit-identity", || {
        let srcs = tenants(96);
        for (m, src) in srcs.iter().enumerate() {
            // One-shot: leader-extracted dense tiles vs shard-side
            // materialization from chunk descriptors.
            let x = Vector::standard_normal(src.ncols(), 0xD0 + m as u64);
            let leader = PlaneHandle::build(src.as_ref(), &config(), &opts(), native())
                .unwrap()
                .execute_once(src.as_ref(), &x)
                .unwrap();
            let shard = PlaneHandle::build(src.as_ref(), &config(), &opts(), native())
                .unwrap()
                .execute_once_shared(src.clone(), &x)
                .unwrap();
            assert_eq!(leader.y, shard.y, "one-shot operand {m} diverged");

            // Resident: program vs program_shared, then identical batches.
            let xs: Vec<Vector> = (0..3)
                .map(|k| Vector::standard_normal(src.ncols(), 0xD8 + (m * 10 + k) as u64))
                .collect();
            let run = |shared: bool| {
                let plane =
                    PlaneHandle::build(src.as_ref(), &config(), &opts(), native()).unwrap();
                let (id, report) = if shared {
                    plane.program_shared(src.clone()).unwrap()
                } else {
                    plane.program(src.as_ref()).unwrap()
                };
                let ys: Vec<Vector> = plane
                    .execute_batch(id, &xs)
                    .unwrap()
                    .solves
                    .into_iter()
                    .map(|s| s.y)
                    .collect();
                (report.chunks_resident, report.mean_wv_iters, ys)
            };
            let (chunks_a, wv_a, ys_a) = run(false);
            let (chunks_b, wv_b, ys_b) = run(true);
            assert_eq!(chunks_a, chunks_b, "operand {m}: resident chunk counts differ");
            assert_eq!(wv_a, wv_b, "operand {m}: write-verify iteration counts differ");
            assert_eq!(ys_a, ys_b, "resident operand {m} diverged");
        }
    });
}

/// Sum of `meliso_subMCA_steals_total` across all shard label series.
fn submca_steals_total() -> f64 {
    meliso::obs::global()
        .snapshot()
        .families
        .iter()
        .filter(|f| f.name == meliso::obs::names::SUBMCA_STEALS)
        .flat_map(|f| f.series.iter())
        .map(|s| match s.value {
            meliso::obs::registry::SeriesValue::Counter(v) => v,
            _ => 0.0,
        })
        .sum()
}

/// An operand whose occupied chunks all land on MCA `(0, 0)` of a 4×2 MCA
/// grid with 32-wide tiles: chunk `(i, j)` maps to MCA `(i mod 4, j mod 2)`,
/// so rows with `(r / 32) % 4 == 0` and columns with `(c / 32) % 2 == 0`
/// confine every nonzero block to one MCA.
fn confined_source(n: usize) -> Arc<dyn MatrixSource> {
    Arc::new(DenseSource::new(Matrix::from_fn(n, n, |r, c| {
        if (r / 32) % 4 == 0 && (c / 32) % 2 == 0 {
            let h = (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (c as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
            (h >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        } else {
            0.0
        }
    })))
}

#[test]
fn forced_sub_mca_steals_stay_bit_identical() {
    bounded("sub-mca-steal-determinism", || {
        // Counter updates are gated on the obs level; turn metrics on so
        // the sub-MCA steal counter below actually records.
        meliso::obs::set_level(meliso::obs::ObsLevel::Metrics);
        // 8 MCAs but only MCA (0, 0) holds chunks: with more shards than
        // occupied MCAs, phase-1 whole-MCA claims leave every other worker
        // empty-handed and batch parallelism exists only through sub-MCA
        // stealing inside MCA 0's chunk grid.
        let config = SystemConfig::new(4, 2, 32);
        let src = confined_source(512);
        let xs: Vec<Vector> = (0..4)
            .map(|k| Vector::standard_normal(src.ncols(), 0xE0 + k))
            .collect();
        let steals_before = submca_steals_total();
        let run = |workers: usize, placement: Placement| {
            let o = opts().with_workers(workers).with_placement(placement);
            let plane = PlaneHandle::build(src.as_ref(), &config, &o, native()).unwrap();
            let (id, report) = plane.program_shared(src.clone()).unwrap();
            assert_eq!(report.mcas_used, 1, "operand not confined to one MCA");
            (0..2)
                .map(|_| {
                    plane
                        .execute_batch(id, &xs)
                        .unwrap()
                        .solves
                        .into_iter()
                        .map(|s| s.y)
                        .collect::<Vec<Vector>>()
                })
                .collect::<Vec<_>>()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 8] {
            for placement in [
                Placement::RoundRobin,
                Placement::LoadBalanced,
                Placement::SparsityAware,
                Placement::TimingAware,
            ] {
                let got = run(workers, placement);
                assert_eq!(
                    reference,
                    got,
                    "{workers} workers, {} diverged under forced sub-MCA stealing",
                    placement.name()
                );
            }
        }
        assert!(
            submca_steals_total() > steals_before,
            "confined operand never triggered a sub-MCA steal across 16 contended batches"
        );
    });
}
