//! Concurrent-admission regression suite for the shared-handle execution
//! plane: many client threads, many resident operands, one shard pool.
//!
//! Three invariants the `PlaneHandle` redesign must uphold:
//!
//! * **bit-identity under multi-tenancy** — N threads solving M operands
//!   concurrently on one plane produce exactly the results of M dedicated
//!   planes (execution noise is counter-based per `(operand, solve,
//!   chunk)`, so scheduling cannot leak into the numerics);
//! * **no deadlock under faults** — a shard panic mid-batch with several
//!   concurrent clients surfaces as a clean typed error on every thread,
//!   within a hard wall-clock bound, never a hang;
//! * **work-stealing determinism** — irregular operands unbalance the
//!   per-shard queues and trigger stealing; the steal order is
//!   timing-dependent, the results must not be.

use meliso::matrices::{generators, BandedSource, DenseSource, MatrixSource};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::testing::faults::FaultBackend;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

const SCENARIO_TIMEOUT: Duration = Duration::from_secs(120);

/// Run `f` on a helper thread and fail the test if it does not finish in
/// [`SCENARIO_TIMEOUT`] — a lost wakeup or admission deadlock trips this
/// bound instead of wedging the whole test run.
fn bounded<T: Send + 'static>(name: &str, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::Builder::new()
        .name(format!("bounded-{name}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .expect("spawn scenario thread");
    match rx.recv_timeout(SCENARIO_TIMEOUT) {
        Ok(v) => v,
        Err(_) => panic!("scenario {name:?} hung past {SCENARIO_TIMEOUT:?} (deadlock regression)"),
    }
}

fn native() -> meliso::runtime::Backend {
    Arc::new(NativeBackend::new())
}

fn config() -> SystemConfig {
    SystemConfig::new(2, 2, 32)
}

fn opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_seed(0x5EED)
        .with_workers(3)
}

/// Mixed tenant set: dense, banded (regular sparsity) and power-law CSR
/// (irregular sparsity, the work-stealing trigger).
fn tenants(n: usize) -> Vec<Arc<dyn MatrixSource>> {
    vec![
        Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 0xA1))),
        Arc::new(BandedSource::new(n, 5, 1.0, 8.0, 0.25, 0xA2)),
        Arc::new(generators::power_law_csr(n, 3, 4.0, 50.0, 0.2, 0xA3)),
        Arc::new(DenseSource::new(Matrix::standard_normal(n, n, 0xA4))),
    ]
}

fn inputs(srcs: &[Arc<dyn MatrixSource>], solves: usize) -> Vec<Vec<Vector>> {
    srcs.iter()
        .enumerate()
        .map(|(m, s)| {
            (0..solves)
                .map(|k| Vector::standard_normal(s.ncols(), 0xB0 + (m * 100 + k) as u64))
                .collect()
        })
        .collect()
}

#[test]
fn concurrent_tenants_match_dedicated_planes_bit_exact() {
    bounded("concurrent-bit-identity", || {
        let srcs = tenants(96);
        let xs = inputs(&srcs, 3);

        // References: each operand on its own dedicated plane, solved
        // sequentially.
        let dedicated: Vec<Vec<Vector>> = srcs
            .iter()
            .zip(&xs)
            .map(|(s, stream)| {
                let plane = PlaneHandle::build(s.as_ref(), &config(), &opts(), native()).unwrap();
                let (id, _) = plane.program(s.as_ref()).unwrap();
                stream
                    .iter()
                    .map(|x| {
                        plane
                            .execute_batch(id, std::slice::from_ref(x))
                            .unwrap()
                            .solves
                            .remove(0)
                            .y
                    })
                    .collect()
            })
            .collect();

        // One shared plane, one client thread per operand, all solving at
        // once through clones of the same handle.
        let plane =
            PlaneHandle::build(srcs[0].as_ref(), &config(), &opts(), native()).unwrap();
        let ids: Vec<OperandId> = srcs
            .iter()
            .map(|s| plane.program(s.as_ref()).unwrap().0)
            .collect();
        let shared: Vec<Vec<Vector>> = std::thread::scope(|scope| {
            let handles: Vec<_> = srcs
                .iter()
                .enumerate()
                .map(|(m, _)| {
                    let plane = plane.clone();
                    let id = ids[m];
                    let stream = &xs[m];
                    scope.spawn(move || {
                        stream
                            .iter()
                            .map(|x| {
                                plane
                                    .execute_batch(id, std::slice::from_ref(x))
                                    .unwrap()
                                    .solves
                                    .remove(0)
                                    .y
                            })
                            .collect::<Vec<Vector>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("client thread"))
                .collect()
        });
        assert_eq!(plane.resident_operands(), srcs.len());
        for (m, (ded, shr)) in dedicated.iter().zip(&shared).enumerate() {
            assert_eq!(ded, shr, "operand {m} diverged under concurrent multi-tenancy");
        }
    });
}

#[test]
fn shard_panic_mid_concurrent_batches_never_deadlocks() {
    bounded("concurrent-shard-panic", || {
        let srcs = tenants(96);
        let xs = inputs(&srcs, 2);
        let backend = FaultBackend::panicking(NativeBackend::new());
        let fault = backend.handle();
        let plane =
            PlaneHandle::build(srcs[0].as_ref(), &config(), &opts(), Arc::new(backend)).unwrap();
        let ids: Vec<OperandId> = srcs
            .iter()
            .map(|s| plane.program(s.as_ref()).unwrap().0)
            .collect();
        // Arm the fault, then let every client fire at once: some batches
        // die on the panicking shard, the rest on the poisoned plane.
        // Every thread must get an error back — no hang, no lost client.
        fault.fail_next_reads(true);
        let errors: Vec<PlaneError> = std::thread::scope(|scope| {
            let handles: Vec<_> = srcs
                .iter()
                .enumerate()
                .map(|(m, _)| {
                    let plane = plane.clone();
                    let id = ids[m];
                    let stream = &xs[m];
                    scope.spawn(move || {
                        let mut errs = Vec::new();
                        for x in stream {
                            if let Err(e) = plane.execute_batch(id, std::slice::from_ref(x)) {
                                errs.push(e);
                            }
                        }
                        errs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread"))
                .collect()
        });
        assert!(!errors.is_empty(), "armed fault produced no errors");
        for e in &errors {
            assert!(
                matches!(e, PlaneError::ShardDead(_) | PlaneError::Failed(_)),
                "{e:?}"
            );
        }
        // The plane is poisoned: later calls fail fast with the root cause.
        assert!(plane.failure().is_some());
        fault.fail_next_reads(false);
        let err = plane
            .execute_batch(ids[0], std::slice::from_ref(&xs[0][0]))
            .unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
    });
}

#[test]
fn work_stealing_is_invisible_in_results() {
    bounded("steal-determinism", || {
        // A power-law CSR operand concentrates occupied chunks on a few
        // block rows, leaving some shard queues long and others empty —
        // exactly the imbalance batch workers steal across.  The steal
        // schedule is timing-dependent and differs run to run; the solve
        // must not.
        let src = generators::power_law_csr(160, 4, 4.0, 60.0, 0.25, 0xC1);
        let xs: Vec<Vector> = (0..3)
            .map(|k| Vector::standard_normal(src.ncols(), 0xC2 + k))
            .collect();
        let run = |workers: usize, placement: Placement| {
            let o = opts().with_workers(workers).with_placement(placement);
            let plane = PlaneHandle::build(&src, &config(), &o, native()).unwrap();
            let (id, _) = plane.program(&src).unwrap();
            // Two rounds: the second round gives the timing-aware policy
            // measured chunk times to redistribute by.
            (0..2)
                .map(|_| {
                    plane
                        .execute_batch(id, &xs)
                        .unwrap()
                        .solves
                        .into_iter()
                        .map(|s| s.y)
                        .collect::<Vec<Vector>>()
                })
                .collect::<Vec<_>>()
        };
        let reference = run(1, Placement::RoundRobin);
        for workers in [2, 3, 4] {
            for placement in [
                Placement::RoundRobin,
                Placement::LoadBalanced,
                Placement::SparsityAware,
                Placement::TimingAware,
            ] {
                // Repeat each configuration so at least some runs take
                // different steal schedules.
                for rep in 0..2 {
                    let got = run(workers, placement);
                    assert_eq!(
                        reference,
                        got,
                        "{workers} workers, {} (rep {rep}) diverged",
                        placement.name()
                    );
                }
            }
        }
    });
}
