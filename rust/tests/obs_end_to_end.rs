//! Observability end-to-end suite: the three contracts the `obs` layer
//! makes to the rest of the framework.
//!
//! 1. **Never perturb numerics** — a solve with full tracing enabled is
//!    bit-identical to the same solve with observability off.
//! 2. **Cover the pipeline** — a resident program + batch execute leaves
//!    at least one span per stage (plan, extract, encode, execute,
//!    gather, reduce) with per-shard lanes, and the rendered Chrome
//!    trace parses back as JSON.
//! 3. **Stable exposition** — the Prometheus text format is pinned by a
//!    golden file (HELP/TYPE lines, label escaping, cumulative
//!    `_bucket`/`_sum`/`_count`), and every exported histogram satisfies
//!    the bucket invariants.
//!
//! The observability level is process-global, so the tests that toggle
//! it serialize on one mutex and restore `Off` on the way out (also on
//! panic, via a drop guard).

use meliso::matrices::{DenseSource, MatrixSource};
use meliso::obs::export::{check_histogram_invariants, prometheus, to_json};
use meliso::obs::registry::Registry;
use meliso::obs::{self, Lane, ObsLevel, Stage, StatusReport};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::util::json::Json;
use std::sync::{Arc, Mutex, OnceLock};

fn obs_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// Restores `ObsLevel::Off` when dropped, so a failing assertion cannot
/// leak an armed level into the other tests.
struct LevelGuard;

impl Drop for LevelGuard {
    fn drop(&mut self) {
        obs::set_level(ObsLevel::Off);
    }
}

fn config() -> SystemConfig {
    SystemConfig::new(2, 2, 32)
}

fn opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_workers(2)
        .with_seed(17)
}

/// One-shot solve on a fresh plane, returning the raw result bits.
fn solve_once(src: &DenseSource, x: &Vector) -> Vec<u64> {
    let plane = ExecutionPlane::build(src, &config(), &opts(), Arc::new(NativeBackend::new()))
        .expect("build plane");
    let report = plane.execute_once(src, x).expect("execute once");
    report.y.data().iter().map(|v| v.to_bits()).collect()
}

#[test]
fn tracing_never_perturbs_numerics() {
    let _g = obs_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _restore = LevelGuard;
    let src = DenseSource::new(Matrix::standard_normal(64, 64, 21));
    let x = Vector::standard_normal(64, 22);

    obs::set_level(ObsLevel::Off);
    let base = solve_once(&src, &x);

    obs::set_level(ObsLevel::Trace);
    obs::recorder().clear();
    let traced = solve_once(&src, &x);

    assert_eq!(base, traced, "tracing changed the solve result bits");
    let (events, _) = obs::recorder().snapshot();
    assert!(!events.is_empty(), "trace level recorded no spans");
}

#[test]
fn resident_serving_traces_every_stage_across_shard_lanes() {
    let _g = obs_lock().lock().unwrap_or_else(|e| e.into_inner());
    let _restore = LevelGuard;
    obs::set_level(ObsLevel::Trace);
    obs::recorder().clear();

    let source: Arc<dyn MatrixSource> =
        Arc::new(DenseSource::new(Matrix::standard_normal(64, 64, 31)));
    let solver = Meliso::with_backend(config(), opts(), Arc::new(NativeBackend::new()));
    let plane = solver.build_plane(source.as_ref()).expect("build plane");
    let session = solver
        .open_session_on(&plane, source)
        .expect("open session");
    let xs: Vec<Vector> = (0..4)
        .map(|i| Vector::standard_normal(64, 40 + i as u64))
        .collect();
    session.solve_batch(&xs).expect("solve batch");

    let (events, _) = obs::recorder().snapshot();
    for stage in Stage::ALL {
        assert!(
            events.iter().any(|e| e.stage == stage),
            "no span recorded for stage {:?}",
            stage
        );
    }
    let mut shard_lanes: Vec<usize> = events
        .iter()
        .filter_map(|e| match e.lane {
            Lane::Shard(s) => Some(s),
            Lane::Leader => None,
        })
        .collect();
    shard_lanes.sort_unstable();
    shard_lanes.dedup();
    assert!(
        shard_lanes.len() >= 2,
        "expected spans from >= 2 shard lanes, got {shard_lanes:?}"
    );

    // The rendered Chrome trace is valid JSON with metadata rows and at
    // least one complete ("X") span event.
    let doc = obs::recorder().chrome_trace();
    let back = Json::parse(&doc.pretty()).expect("chrome trace parses");
    let items = back
        .get("traceEvents")
        .and_then(|t| t.as_arr())
        .expect("traceEvents array");
    assert!(items
        .iter()
        .any(|i| i.get("ph").and_then(|p| p.as_str()) == Some("X")));
    assert!(items
        .iter()
        .any(|i| i.get("ph").and_then(|p| p.as_str()) == Some("M")));

    // The metrics side of the same run: the exported snapshot assembles
    // into a status report with per-shard rows and recorded solves, and
    // every histogram satisfies the exposition invariants.
    let snap = obs::global().snapshot();
    check_histogram_invariants(&snap).expect("histogram invariants");
    let report = StatusReport::from_json(&to_json(&snap, 5.0)).expect("status report");
    assert!(
        report.shards.len() >= 2,
        "status surfaced {} shard rows",
        report.shards.len()
    );
    assert!(report.solve_count > 0, "status surfaced no served solves");
    assert!(report.energy_write_j.unwrap_or(0.0) > 0.0);
}

/// A deterministic registry whose exposition the golden file pins.
fn golden_registry() -> Registry {
    let r = Registry::new();
    let help = "Chunks executed by the demo plane";
    r.counter("demo_chunks_total", help, &[("shard", "0")]).add(8.0);
    r.counter("demo_chunks_total", help, &[("shard", "1")]).add(3.0);
    r.counter(
        "demo_escaped_total",
        "Label escaping: backslash \\ quote \" newline \n end",
        &[("operand", "a\\b\"c\nd")],
    )
    .inc();
    r.gauge("demo_slots_in_use", "Tile slots currently held", &[])
        .set(6.0);
    let h = r.histogram(
        "demo_latency_seconds",
        "Demo latency",
        &[("operand", "op0")],
        &[0.25, 1.0, 4.0],
    );
    // Powers of two, so the `_sum` renders exactly.
    h.observe(0.125);
    h.observe(0.5);
    h.observe(2.0);
    h.observe(8.0);
    r
}

#[test]
fn prometheus_exposition_matches_golden_file() {
    let snap = golden_registry().snapshot();
    let got = prometheus(&snap);
    let want = include_str!("data/metrics_golden.prom");
    assert_eq!(got, want, "Prometheus exposition drifted from the golden file");
    check_histogram_invariants(&snap).unwrap();
}

#[test]
fn golden_document_round_trips_through_json() {
    let snap = golden_registry().snapshot();
    let doc = to_json(&snap, 2.0);
    let back = Json::parse(&doc.pretty()).expect("JSON export parses");
    assert_eq!(back.get("schema").and_then(|s| s.as_f64()), Some(1.0));
    let hist = back
        .get("metrics")
        .and_then(|m| m.get("demo_latency_seconds"))
        .expect("histogram family");
    assert_eq!(hist.get("type").and_then(|t| t.as_str()), Some("histogram"));
    let series = &hist.get("series").and_then(|s| s.as_arr()).unwrap()[0];
    assert_eq!(series.get("count").and_then(|c| c.as_f64()), Some(4.0));
    assert_eq!(series.get("sum").and_then(|s| s.as_f64()), Some(10.625));
}
