//! End-to-end coverage for *irregular* sparse operands (`CsrSource`):
//! the ISSUE-5 acceptance path.  An irregular operand must solve through
//! both execution paths — one-shot (`solve_source`) and resident
//! (`program`/`execute_batch` behind a `Session`) — bit-identical across
//! shard counts and placement policies, and a Matrix-Market file must
//! ride the same registry route the synthetic testbed uses.

use meliso::device::materials::Material;
use meliso::matrices::{generators, registry, MatrixSource};
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use std::sync::Arc;

fn native_solver(config: SystemConfig, opts: SolveOptions) -> Meliso {
    Meliso::with_backend(config, opts, Arc::new(NativeBackend::new()))
}

fn base_opts() -> SolveOptions {
    SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_seed(42)
}

/// A small irregular operand: arrowhead + superdiagonal, SPD, n = 120.
fn arrow120() -> Arc<dyn MatrixSource> {
    Arc::new(generators::arrowhead_csr(120, 4.0, 50.0, 0.2, 0xA1))
}

#[test]
fn one_shot_bit_identical_across_shards_and_placements() {
    let src = arrow120();
    let x = Vector::standard_normal(120, 7);
    let cfg = SystemConfig::new(2, 2, 32);
    let mut results: Vec<(String, Vector)> = Vec::new();
    for workers in [1usize, 2, 4] {
        for placement in [
            Placement::RoundRobin,
            Placement::LoadBalanced,
            Placement::SparsityAware,
        ] {
            let solver = native_solver(
                cfg,
                base_opts().with_workers(workers).with_placement(placement),
            );
            let report = solver.solve_source(src.as_ref(), &x).unwrap();
            // Sparsity-aware skipping engaged: the arrowhead leaves most
            // of the 4x4 chunk grid unoccupied.
            assert!(report.chunks_skipped > 0, "w{workers}/{}", placement.name());
            assert!(report.rel_err_l2 < 0.1, "w{workers}: {}", report.rel_err_l2);
            results.push((format!("w{workers}/{}", placement.name()), report.y));
        }
    }
    for (label, y) in &results[1..] {
        assert_eq!(*y, results[0].1, "{label} differs from {}", results[0].0);
    }
}

#[test]
fn resident_bit_identical_across_shards_and_placements() {
    let src = arrow120();
    let xs: Vec<Vector> = (0..4)
        .map(|i| Vector::standard_normal(120, 100 + i))
        .collect();
    let cfg = SystemConfig::new(2, 2, 32);
    let mut results: Vec<(String, Vec<Vector>)> = Vec::new();
    for workers in [1usize, 3] {
        for placement in [Placement::RoundRobin, Placement::SparsityAware] {
            let solver = native_solver(
                cfg,
                base_opts().with_workers(workers).with_placement(placement),
            );
            let session = solver.open_session(src.clone()).unwrap();
            let solves = session.solve_batch(&xs).unwrap();
            let ys: Vec<Vector> = solves.into_iter().map(|s| s.y).collect();
            results.push((format!("w{workers}/{}", placement.name()), ys));
        }
    }
    for (label, ys) in &results[1..] {
        assert_eq!(*ys, results[0].1, "{label} differs from {}", results[0].0);
    }
    // And the served results are accurate against the exact matvec.
    let b = src.matvec(&xs[0]);
    let err = results[0].1[0].sub(&b).norm_l2() / b.norm_l2();
    assert!(err < 0.1, "{err}");
}

#[test]
fn irregular_operand_solves_ax_equals_b_via_cg() {
    let src = arrow120();
    let x_star = Vector::standard_normal(120, 31);
    let b = src.matvec(&x_star);
    let solver = native_solver(
        SystemConfig::new(2, 2, 64),
        base_opts().with_wv_iters(3).with_placement(Placement::SparsityAware),
    );
    let report = solver
        .solve_system(
            src.clone(),
            &b,
            &IterOptions::default()
                .with_method(Method::Cg)
                .with_tol(1e-5)
                .with_max_iters(80)
                .with_refinements(30),
        )
        .unwrap();
    assert!(report.converged, "rel {}", report.rel_residual);
    assert!(report.rel_residual <= 1e-5);
    assert_eq!(report.programming_passes, 1);
    let err = report.x.sub(&x_star).norm_l2() / x_star.norm_l2();
    assert!(err < 1e-2, "{err}");
}

#[test]
fn irregular_operands_share_one_resident_plane() {
    // Two different irregular tenants resident on ONE shard pool,
    // bit-identical to dedicated planes.
    let a: Arc<dyn MatrixSource> =
        Arc::new(generators::power_law_csr(96, 3, 4.0, 50.0, 0.2, 0xB2));
    let c: Arc<dyn MatrixSource> =
        Arc::new(generators::block_diag_csr(96, 32, 4.0, 50.0, 0.2, 0xB3));
    let solver = native_solver(SystemConfig::new(2, 2, 32), base_opts().with_workers(2));
    let x = Vector::standard_normal(96, 5);

    let dedicated_a = solver.open_session(a.clone()).unwrap().solve(&x).unwrap().y;
    let dedicated_c = solver.open_session(c.clone()).unwrap().solve(&x).unwrap().y;

    let plane = solver.build_plane(a.as_ref()).unwrap();
    let sa = solver.open_session_on(&plane, a.clone()).unwrap();
    let sc = solver.open_session_on(&plane, c.clone()).unwrap();
    assert_eq!(plane.resident_operands(), 2);
    assert_eq!(sa.solve(&x).unwrap().y, dedicated_a);
    assert_eq!(sc.solve(&x).unwrap().y, dedicated_c);
}

#[test]
fn bundled_mtx_fixture_runs_end_to_end() {
    // The CI smoke fixture, through the registry's file route: both the
    // `mtx:` prefix and the bare path must load, one-shot-solve and
    // CG-solve.  Integration tests run from the package root.
    let src = registry::build("mtx:data/arrow16.mtx").unwrap();
    assert_eq!((src.nrows(), src.ncols()), (16, 16));
    let same = registry::build("data/arrow16.mtx").unwrap();
    assert_eq!(same.nrows(), 16);

    let x = Vector::standard_normal(16, 3);
    let solver = native_solver(SystemConfig::single_mca(32), base_opts());
    let report = solver.solve_source(src.as_ref(), &x).unwrap();
    assert!(report.rel_err_l2 < 0.1, "{}", report.rel_err_l2);

    let x_star = Vector::standard_normal(16, 4);
    let b = src.matvec(&x_star);
    let conv = solver
        .solve_system(src, &b, &IterOptions::default().with_method(Method::Cg))
        .unwrap();
    assert!(conv.converged, "rel {}", conv.rel_residual);
    assert_eq!(conv.programming_passes, 1);
}

#[test]
fn csr_plan_skips_empty_chunk_columns_for_block_diagonal() {
    use meliso::virtualization::{ChunkPlan, SystemGeometry};
    let src = generators::block_diag_csr(512, 32, 4.0, 50.0, 0.2, 0xB4);
    let plan = ChunkPlan::new(SystemGeometry::new(2, 2, 16), 512, 512);
    let planned = plan.nonzero_chunks(&src).count();
    assert!(
        planned * 2 < plan.total_chunks(),
        "block-diagonal should occupy a small fraction of the grid: {planned} of {}",
        plan.total_chunks()
    );
}
