//! Robustness study: the two-tier EC under the extended non-idealities
//! (ADC quantization, retention drift, IR drop) — the paper's §1 motivation
//! ("sneak paths and parasitic interconnect resistances") exercised as
//! failure injection on the full pipeline.

use meliso::device::materials::Material;
use meliso::device::nonideal::{AdcModel, DriftModel, IrDropModel, NonIdealExt};
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use std::sync::Arc;

fn run(ext: NonIdealExt, ec: bool, seed: u64) -> f64 {
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 21);
    let solver = Meliso::with_backend(
        SystemConfig::single_mca(128),
        SolveOptions::default()
            .with_device(Material::TaOxHfOx)
            .with_ec(ec)
            .with_wv_iters(2)
            .with_seed(seed)
            .with_nonideal(ext),
        Arc::new(NativeBackend::new()),
    );
    let reps = 4;
    (0..reps)
        .map(|r| {
            let s = Meliso::with_backend(
                *solver.config(),
                solver.options().clone().with_seed(seed + r),
                Arc::new(NativeBackend::new()),
            );
            s.solve_source(source.as_ref(), &x).unwrap().rel_err_l2
        })
        .sum::<f64>()
        / reps as f64
}

#[test]
fn adc_quantization_floors_accuracy() {
    let coarse = run(
        NonIdealExt {
            adc: AdcModel::new(4),
            ..Default::default()
        },
        true,
        100,
    );
    let fine = run(
        NonIdealExt {
            adc: AdcModel::new(12),
            ..Default::default()
        },
        true,
        100,
    );
    let none = run(NonIdealExt::default(), true, 100);
    assert!(coarse > fine, "coarse {coarse:.4} fine {fine:.4}");
    assert!(fine < none * 3.0, "12-bit ADC should be near-transparent");
    // 4-bit ADC floors around 1/2^4 ~ 6%.
    assert!(coarse > 0.01, "{coarse:.4}");
}

#[test]
fn drift_degrades_raw_more_than_ec_corrects() {
    // Uniform drift is a *common-mode* multiplicative error on Ã — exactly
    // the structure the first-order EC cancels. EC must recover most of it.
    let ext = NonIdealExt {
        drift: DriftModel::new(0.05, 1e4),
        ..Default::default()
    };
    let raw = run(ext, false, 200);
    let ec = run(ext, true, 200);
    let raw_clean = run(NonIdealExt::default(), false, 200);
    assert!(raw > raw_clean * 1.05, "drift should hurt raw: {raw:.4} vs {raw_clean:.4}");
    assert!(ec < raw * 0.3, "EC should absorb drift: ec {ec:.4} raw {raw:.4}");
}

#[test]
fn ir_drop_hurts_and_ec_partially_recovers() {
    let ext = NonIdealExt {
        ir_drop: IrDropModel::new(0.1),
        ..Default::default()
    };
    let raw = run(ext, false, 300);
    let ec = run(ext, true, 300);
    let raw_clean = run(NonIdealExt::default(), false, 300);
    assert!(raw > raw_clean, "IR drop should hurt raw accuracy");
    assert!(ec < raw, "EC should recover part of the IR-drop error");
}

#[test]
fn stacked_nonidealities_still_converge_with_ec() {
    let ext = NonIdealExt {
        adc: AdcModel::new(10),
        drift: DriftModel::new(0.02, 1e3),
        ir_drop: IrDropModel::new(0.05),
    };
    let ec = run(ext, true, 400);
    assert!(ec < 0.1, "stacked non-idealities with EC: {ec:.4}");
}
