//! PJRT integration: the artifact path must agree with the native twin.
//!
//! These tests require `artifacts/` (run `make artifacts` first); they
//! skip gracefully when artifacts are missing so `cargo test` works in a
//! fresh checkout.

use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::runtime::native::NativeBackend;
use meliso::runtime::pjrt::default_artifact_dir;
use meliso::runtime::service::PjrtBackend;
use meliso::runtime::{Backend, EcMvmRequest, ExecBackend};
use meliso::util::rng::Rng;
use std::sync::Arc;

fn pjrt() -> Option<Arc<PjrtBackend>> {
    match PjrtBackend::start(&default_artifact_dir()) {
        Ok(b) => Some(Arc::new(b)),
        Err(e) => {
            eprintln!("skipping PJRT test: {e}");
            None
        }
    }
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn pjrt_mvm_matches_native() {
    let Some(backend) = pjrt() else { return };
    let native = NativeBackend::new();
    for n in [32usize, 64, 128, 256] {
        let a = rand_vec(n * n, n as u64);
        let x = rand_vec(n, n as u64 + 1);
        let got = backend.mvm(n, a.clone(), x.clone()).unwrap();
        let want = native.mvm(n, a, x).unwrap();
        for i in 0..n {
            let tol = 1e-3 * (1.0 + want[i].abs());
            assert!(
                (got[i] - want[i]).abs() < tol,
                "n={n} i={i}: pjrt {} vs native {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn pjrt_ec_mvm_matches_native() {
    let Some(backend) = pjrt() else { return };
    let native = NativeBackend::new();
    let n = 128;
    let a = rand_vec(n * n, 1);
    let at: Vec<f32> = a.iter().map(|v| v * 1.013).collect();
    let x = rand_vec(n, 2);
    let xt: Vec<f32> = x.iter().map(|v| v * 0.984).collect();
    let mut minv = vec![0.0f32; n * n];
    for i in 0..n {
        minv[i * n + i] = 1.0;
    }
    let nv = rand_vec(n, 3).iter().map(|v| 1.0 + 0.001 * v).collect::<Vec<_>>();
    let nu = rand_vec(n, 4).iter().map(|v| 1.0 + 0.001 * v).collect::<Vec<_>>();
    let ny = rand_vec(n, 5).iter().map(|v| 1.0 + 0.001 * v).collect::<Vec<_>>();
    let req = EcMvmRequest {
        n,
        a,
        at,
        x,
        xt,
        minv,
        nv,
        nu,
        ny,
    };
    let req2 = EcMvmRequest {
        n: req.n,
        a: req.a.clone(),
        at: req.at.clone(),
        x: req.x.clone(),
        xt: req.xt.clone(),
        minv: req.minv.clone(),
        nv: req.nv.clone(),
        nu: req.nu.clone(),
        ny: req.ny.clone(),
    };
    let got = backend.ec_mvm(req).unwrap();
    let want = native.ec_mvm(req2).unwrap();
    for (g, w) in [(&got.y_raw, &want.y_raw), (&got.p, &want.p), (&got.y_corr, &want.y_corr)] {
        for i in 0..n {
            let tol = 2e-3 * (1.0 + w[i].abs());
            assert!((g[i] - w[i]).abs() < tol, "i={i}: {} vs {}", g[i], w[i]);
        }
    }
}

#[test]
fn pjrt_full_solve_matches_native_statistically() {
    let Some(backend) = pjrt() else { return };
    let source = registry::build("iperturb66").unwrap();
    let x = Vector::standard_normal(66, 6);
    let run = |b: Backend| {
        let solver = Meliso::with_backend(
            SystemConfig::single_mca(128),
            SolveOptions::default()
                .with_device(Material::TaOxHfOx)
                .with_wv_iters(2)
                .with_seed(77),
            b,
        );
        solver.solve_source(source.as_ref(), &x).unwrap()
    };
    let p = run(backend);
    let n = run(Arc::new(NativeBackend::new()));
    // Same seeds, same noise draws; only the MVM arithmetic differs (both
    // f32), so the reports must agree tightly.
    assert!(
        (p.rel_err_l2 - n.rel_err_l2).abs() < 0.2 * n.rel_err_l2.max(1e-6),
        "pjrt {} vs native {}",
        p.rel_err_l2,
        n.rel_err_l2
    );
    assert_eq!(p.chunks_total, n.chunks_total);
    assert!((p.ew_mean - n.ew_mean).abs() < 1e-12);
}

#[test]
fn pjrt_rejects_unknown_tile() {
    let Some(backend) = pjrt() else { return };
    let a = vec![0.0f32; 48 * 48];
    let x = vec![0.0f32; 48];
    assert!(backend.mvm(48, a, x).is_err());
}
