"""Pure-jnp/numpy oracle for the L1 kernels and L2 model.

Everything here is the *specification*: the Pallas kernels and the lowered
HLO artifacts are correct iff they match these functions to float32 tolerance.
The Rust native backend mirrors these semantics (see rust/src/runtime/native.rs).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mvm_ref(a, x):
    """Reference MVM: ``(m, n) @ (n, 1) -> (m, 1)``."""
    return a @ x


def ec_combine_ref(v, u, y):
    """First-order combine ``p = v + u - y`` (v=Ãx, u=Ax̃, y=Ãx̃)."""
    return v + u - y


def first_order_ref(a, at, x, xt):
    """Full first-order EC: three products then combine."""
    return ec_combine_ref(at @ x, a @ xt, at @ xt)


def difference_matrix(n: int, h: float = -1.0) -> np.ndarray:
    """Paper Eq. 9: first-order difference matrix L (diag 1, superdiag h)."""
    l = np.eye(n)
    l[np.arange(n - 1), np.arange(1, n)] = h
    return l


def denoise_inverse(n: int, lam: float, h: float = -1.0) -> np.ndarray:
    """Closed-form denoiser matrix ``(I + λ LᵀL)⁻¹`` (paper Eq. 10).

    Built in float64 then cast by callers; ``I + λLᵀL`` is SPD tridiagonal so
    the inverse is well defined for every λ > 0.
    """
    l = difference_matrix(n, h)
    return np.linalg.inv(np.eye(n) + lam * (l.T @ l))


def denoise_ref(p, lam: float, h: float = -1.0):
    """Apply the denoiser digitally (no encoding noise)."""
    n = p.shape[0]
    minv = denoise_inverse(n, lam, h).astype(np.float32)
    return jnp.asarray(minv) @ p


def corrected_mvm_ref(a, at, x, xt, minv, nv=None, nu=None, ny=None):
    """Full two-tier EC pipeline oracle.

    Returns ``(y_raw, p, y_corr)`` matching the ``ec_mvm`` artifact contract:
      y_raw  = Ãx̃ ∘ ny                  (uncorrected measured product)
      p      = Ãx∘nv + Ax̃∘nu − Ãx̃∘ny  (first-order corrected)
      y_corr = M̃inv @ p                 (second-order denoised, in-memory)

    ``nv/nu/ny`` are per-element multiplicative read-noise vectors
    (default: ideal readout, all ones).
    """
    ones = np.ones_like(np.asarray(x))
    nv = ones if nv is None else nv
    nu = ones if nu is None else nu
    ny = ones if ny is None else ny
    y = at @ xt
    p = (at @ x) * nv + (a @ xt) * nu - y * ny
    y_corr = minv @ p
    return y * ny, p, y_corr
