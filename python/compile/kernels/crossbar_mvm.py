"""L1 Pallas kernel: tiled crossbar matrix-vector multiplication.

This is the compute hot-spot of MELISO+: every analog MVM a memory crossbar
array (MCA) performs is simulated as a dense tile MVM.  The Pallas tiling
mirrors the physical structure:

  * one ``BlockSpec`` block of ``A``  == one physical crossbar subarray read,
  * the grid dimension over column-blocks == chunked analog bitline summation
    (partial currents accumulated by the peripheral circuitry),
  * VMEM staging of a block == biasing the subarray's wordlines.

On a real TPU the (128, 128) block feeds the MXU systolic array directly
(f32 here; bf16 on hardware).  The kernel MUST be lowered with
``interpret=True`` in this image: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Physical subarray tile mirrored by the BlockSpec.  128 matches both the MXU
# systolic dimension and a common crossbar subarray size.
DEFAULT_BLOCK = 128


def _mvm_kernel(a_ref, x_ref, y_ref):
    """One grid step: accumulate a (bm, bn) @ (bn, 1) partial product."""
    # First column-block initializes the accumulator ("reset the integrator").
    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += a_ref[...] @ x_ref[...]


def _block_for(n: int, block: int) -> int:
    return n if n < block else block


@functools.partial(jax.jit, static_argnames=("block",))
def crossbar_mvm(a: jax.Array, x: jax.Array, *, block: int = DEFAULT_BLOCK):
    """Compute ``a @ x`` with a crossbar-tiled Pallas kernel.

    Args:
      a: ``(m, n)`` matrix (the encoded conductance image of the operand).
      x: ``(n, 1)`` column vector (the applied wordline voltages).
      block: tile edge; both ``m`` and ``n`` must be divisible by the
        resolved block (the virtualization layer zero-pads to guarantee it).

    Returns:
      ``(m, 1)`` result vector (the integrated bitline currents).
    """
    m, n = a.shape
    bm = _block_for(m, block)
    bn = _block_for(n, block)
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by block ({bm},{bn})")

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mvm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), a.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(a, x)


def _mvm_batched_kernel(a_ref, x_ref, y_ref):
    """Batched grid step: (bm, bn) @ (bn, b) partial products."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += a_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def crossbar_mvm_batched(a: jax.Array, xs: jax.Array, *, block: int = DEFAULT_BLOCK):
    """Batched crossbar MVM: ``a @ xs`` with ``xs`` of shape ``(n, b)``.

    The TPU-deployment extension documented in DESIGN.md
    §Hardware-Adaptation: a rank-1 matvec leaves the MXU systolic array
    memory-bound (arithmetic intensity ~0.5 flop/B); batching ``b`` input
    vectors raises intensity ~b-fold, which is how multiple MVM requests
    sharing one encoded operand would be served on real hardware.  On the
    analog side this corresponds to time-multiplexing ``b`` wordline bias
    patterns over one programmed crossbar.
    """
    m, n = a.shape
    n2, b = xs.shape
    if n != n2:
        raise ValueError(f"dim mismatch: A is {a.shape}, xs is {xs.shape}")
    bm = _block_for(m, block)
    bn = _block_for(n, block)
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by block ({bm},{bn})")

    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mvm_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, b), a.dtype),
        interpret=True,
    )(a, xs)
