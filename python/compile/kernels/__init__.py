# L1: Pallas kernels for the paper's compute hot-spot (crossbar MVM) and the
# first-order EC combine, plus the pure-jnp oracle (ref.py).
from .crossbar_mvm import crossbar_mvm, crossbar_mvm_batched  # noqa: F401
from .ec_combine import ec_combine  # noqa: F401
