"""L2: the MELISO+ per-tile compute graph.

The model is the paper's ``correctedMatVecMul`` (Supplementary Alg. 6) *after*
the encoding step: the Rust coordinator owns the stochastic write–verify
protocols and hands this graph the true operands (``a``, ``x``), their encoded
(noisy) images (``at``, ``xt``), and the encoded denoiser matrix ``minv``.
The graph performs the four crossbar MVMs and the first-order combine — all of
which lower into a single HLO module per tile size.

Shapes are static per artifact: ``n ∈ {32, 64, 128, 256, 512, 1024}`` with the
virtualization layer responsible for zero-padding to the nearest tile size.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import crossbar_mvm, ec_combine

#: Tile sizes for which AOT artifacts are produced.  1024 is the paper's
#: largest array cell size (Fig. 4/5); 32 its smallest.
TILE_SIZES = (32, 64, 128, 256, 512, 1024)


#: Pallas block edge used when lowering AOT artifacts.  128 mirrors the
#: physical crossbar subarray / MXU tile (DESIGN.md §Hardware-Adaptation);
#: the CPU-PJRT artifacts are lowered with the *full tile* as one block
#: (grid 1x1) because interpret-mode grid emulation (dynamic-slice loops)
#: dominates XLA-CPU runtime — a 60-100x hot-path win measured in
#: EXPERIMENTS.md §Perf.  On a real TPU target this constant goes back to
#: 128 and the grid pipelines through VMEM.
AOT_FULL_TILE_BLOCK = 4096  # >= max tile size -> resolved block = n


def mvm(at: jax.Array, xt: jax.Array) -> tuple[jax.Array]:
    """No-EC path: the raw in-memory product ``Ãx̃``.

    Returns a 1-tuple so every artifact uniformly lowers with
    ``return_tuple=True`` (see aot.py / the rust loader's ``to_tuple``).
    """
    return (crossbar_mvm(at, xt, block=AOT_FULL_TILE_BLOCK),)


def ec_mvm(
    a: jax.Array,
    at: jax.Array,
    x: jax.Array,
    xt: jax.Array,
    minv: jax.Array,
    nv: jax.Array,
    nu: jax.Array,
    ny: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Two-tier error-corrected MVM for one tile.

    Args:
      a:    true operand matrix ``(n, n)``.
      at:   encoded (noisy) matrix ``Ã``.
      x:    true input vector ``(n, 1)``.
      xt:   encoded (noisy) vector ``x̃``.
      minv: encoded denoiser ``(I + λLᵀL)⁻¹`` — itself programmed onto the
            crossbar by the coordinator, per the paper.
      nv/nu/ny: ``(n, 1)`` multiplicative read-noise vectors for the three
            measured products (generated per call by the coordinator; ones
            for an ideal readout).

    Returns:
      ``(y_raw, p, y_corr)``:
        y_raw  = Ãx̃ ∘ ny                   — uncorrected measured product,
        p      = Ãx∘nv + Ax̃∘nu − Ãx̃∘ny   — first-order corrected (Eq. 7),
        y_corr = M̃inv p                    — second-order denoised (Eq. 10).
    """
    blk = AOT_FULL_TILE_BLOCK
    v = crossbar_mvm(at, x, block=blk)   # Ãx
    u = crossbar_mvm(a, xt, block=blk)   # Ax̃
    y_raw = crossbar_mvm(at, xt, block=blk)  # Ãx̃
    p = ec_combine(v, u, y_raw, nv, nu, ny, block=blk)
    y_corr = crossbar_mvm(minv, p, block=blk)
    return (y_raw * ny, p, y_corr)


def mvm_specs(n: int):
    """Example-arg specs for lowering ``mvm`` at tile size ``n``."""
    mat = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = jax.ShapeDtypeStruct((n, 1), jnp.float32)
    return (mat, vec)


def ec_mvm_specs(n: int):
    """Example-arg specs for lowering ``ec_mvm`` at tile size ``n``."""
    mat = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = jax.ShapeDtypeStruct((n, 1), jnp.float32)
    return (mat, mat, vec, vec, mat, vec, vec, vec)
