"""AOT pipeline: lower the L2 model to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()`` / serialized HloModuleProto) is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published ``xla`` rust crate)
rejects (``proto.id() <= INT_MAX``).  The HLO text parser reassigns ids, so text
round-trips cleanly — see /opt/xla-example/gen_hlo.py.

Run once at build time (``make artifacts``); the Rust binary is self-contained
afterwards.  Python is never on the request path.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts [--sizes 32,64]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _sha256(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()


def build(out_dir: str, sizes) -> dict:
    """Build every artifact and the manifest; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "schema": 1,
        "dtype": "f32",
        "tile_sizes": list(sizes),
        "artifacts": {},
    }
    for n in sizes:
        for name, fn, specs, outputs in (
            ("mvm", model.mvm, model.mvm_specs(n), ["y_raw"]),
            ("ec_mvm", model.ec_mvm, model.ec_mvm_specs(n), ["y_raw", "p", "y_corr"]),
        ):
            text = lower_artifact(fn, specs)
            fname = f"{name}_{n}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"][f"{name}_{n}"] = {
                "file": fname,
                "tile": n,
                "inputs": len(specs),
                "outputs": outputs,
                "sha256": _sha256(text),
                "bytes": len(text),
            }
            print(f"  wrote {fname}  ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--sizes",
        default=",".join(str(s) for s in model.TILE_SIZES),
        help="comma-separated tile sizes",
    )
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",") if s]
    build(args.out_dir, sizes)


if __name__ == "__main__":
    main()
