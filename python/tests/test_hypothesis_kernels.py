# Property-based sweeps over the Pallas kernel's shape/value space
# (hypothesis), asserting allclose against the pure-jnp oracle (ref.py).
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import crossbar_mvm, crossbar_mvm_batched, ec_combine
from compile.kernels import ref

# Shapes are multiples of 8 (we pass block=8 to keep interpret-mode runtime
# bounded) up to a few hundred; values span typical conductance-scaled ranges.
dims = st.integers(min_value=1, max_value=24).map(lambda k: 8 * k)
scales = st.sampled_from([1e-3, 1.0, 1e2, 1.8e4])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(rng, shape, scale):
    return (scale * rng.standard_normal(shape)).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, scale=scales, seed=seeds)
def test_mvm_matches_ref_over_shapes(m, n, scale, seed):
    rng = np.random.default_rng(seed)
    a, x = _rand(rng, (m, n), scale), _rand(rng, (n, 1), 1.0)
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x), block=8))
    want = ref.mvm_ref(a, x)
    tol = max(1e-4, 1e-6 * scale * n)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=tol)


@settings(max_examples=25, deadline=None)
@given(m=dims, seed=seeds)
def test_ec_combine_matches_ref_over_shapes(m, seed):
    rng = np.random.default_rng(seed)
    v, u, y = (_rand(rng, (m, 1), 1.0) for _ in range(3))
    got = np.asarray(
        ec_combine(jnp.asarray(v), jnp.asarray(u), jnp.asarray(y), block=8)
    )
    np.testing.assert_allclose(got, ref.ec_combine_ref(v, u, y), rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=dims, eps=st.sampled_from([1e-4, 1e-3, 1e-2]), seed=seeds)
def test_first_order_identity_algebra(n, eps, seed):
    # ref-level property: p = Ax(1 - εaεx) exactly (rank-1 multiplicative
    # error model of the paper, per-row εa and shared εx scalar here).
    rng = np.random.default_rng(seed)
    a = _rand(rng, (n, n), 1.0)
    x = _rand(rng, (n, 1), 1.0)
    ea = np.float32(eps)
    ex = np.float32(-eps)
    at = a * (1 + ea)
    xt = x * (1 + ex)
    p = np.asarray(ref.first_order_ref(a, at, x, xt))
    want = (a @ x) * (1 - ea * ex)
    np.testing.assert_allclose(p, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(m=dims, n=dims, b=st.integers(min_value=1, max_value=8), seed=seeds)
def test_batched_mvm_matches_ref_over_shapes(m, n, b, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, n), 1.0)
    xs = _rand(rng, (n, b), 1.0)
    got = np.asarray(crossbar_mvm_batched(jnp.asarray(a), jnp.asarray(xs), block=8))
    np.testing.assert_allclose(got, a @ xs, rtol=2e-4, atol=1e-3)
