# pytest: kernel vs ref allclose — the CORE correctness signal for L1.
import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import crossbar_mvm, crossbar_mvm_batched, ec_combine
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _rand(shape, scale=1.0):
    return (scale * RNG.standard_normal(shape)).astype(np.float32)


@pytest.mark.parametrize("n", [8, 32, 64, 128, 256, 512])
def test_crossbar_mvm_square(n):
    a, x = _rand((n, n)), _rand((n, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    want = ref.mvm_ref(a, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("m,n", [(128, 256), (256, 128), (384, 128), (64, 32)])
def test_crossbar_mvm_rect(m, n):
    a, x = _rand((m, n)), _rand((n, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, ref.mvm_ref(a, x), rtol=2e-5, atol=2e-4)


def test_crossbar_mvm_small_block_resolution():
    # n smaller than the default block resolves the block to n.
    a, x = _rand((16, 16)), _rand((16, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, ref.mvm_ref(a, x), rtol=2e-5, atol=2e-4)


def test_crossbar_mvm_custom_block():
    a, x = _rand((128, 128)), _rand((128, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x), block=32))
    np.testing.assert_allclose(got, ref.mvm_ref(a, x), rtol=2e-5, atol=2e-4)


def test_crossbar_mvm_rejects_indivisible():
    a, x = _rand((130, 130)), _rand((130, 1))
    with pytest.raises(ValueError):
        crossbar_mvm(jnp.asarray(a), jnp.asarray(x))


def test_crossbar_mvm_zero_matrix():
    a = np.zeros((64, 64), np.float32)
    x = _rand((64, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    assert np.all(got == 0.0)


def test_crossbar_mvm_identity():
    n = 128
    a = np.eye(n, dtype=np.float32)
    x = _rand((n, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, x, rtol=1e-6, atol=1e-6)


def test_crossbar_mvm_large_magnitudes():
    # bcsstk02-like spectral norm ~1.8e4 must not overflow f32 accumulation.
    a, x = _rand((128, 128), scale=1.8e4), _rand((128, 1))
    got = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(x)))
    np.testing.assert_allclose(got, ref.mvm_ref(a, x), rtol=1e-4, atol=1e-1)


@pytest.mark.parametrize("m", [8, 128, 384])
def test_ec_combine_matches_ref(m):
    v, u, y = _rand((m, 1)), _rand((m, 1)), _rand((m, 1))
    got = np.asarray(ec_combine(jnp.asarray(v), jnp.asarray(u), jnp.asarray(y)))
    np.testing.assert_allclose(got, ref.ec_combine_ref(v, u, y), rtol=1e-6, atol=1e-6)


def test_ec_combine_shape_mismatch():
    with pytest.raises(ValueError):
        ec_combine(jnp.zeros((8, 1)), jnp.zeros((16, 1)), jnp.zeros((8, 1)))


def test_ec_combine_exact_cancellation():
    # With v == y, p == u exactly (elementwise f32 arithmetic).
    v = _rand((128, 1))
    u = _rand((128, 1))
    got = np.asarray(ec_combine(jnp.asarray(v), jnp.asarray(u), jnp.asarray(v)))
    np.testing.assert_allclose(got, u, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("b", [1, 4, 8])
def test_crossbar_mvm_batched_matches_ref(b):
    m, n = 128, 64
    a, xs = _rand((m, n)), _rand((n, b))
    got = np.asarray(crossbar_mvm_batched(jnp.asarray(a), jnp.asarray(xs)))
    np.testing.assert_allclose(got, a @ xs, rtol=2e-5, atol=2e-4)


def test_crossbar_mvm_batched_consistent_with_single():
    n, b = 64, 4
    a, xs = _rand((n, n)), _rand((n, b))
    batched = np.asarray(crossbar_mvm_batched(jnp.asarray(a), jnp.asarray(xs)))
    for k in range(b):
        single = np.asarray(crossbar_mvm(jnp.asarray(a), jnp.asarray(xs[:, k : k + 1])))
        np.testing.assert_allclose(batched[:, k : k + 1], single, rtol=2e-5, atol=2e-4)


def test_crossbar_mvm_batched_rejects_mismatch():
    with pytest.raises(ValueError):
        crossbar_mvm_batched(jnp.zeros((32, 32)), jnp.zeros((16, 4)))
