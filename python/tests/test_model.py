# pytest: L2 model semantics — EC cancellation properties and the artifact
# contract (y_raw, p, y_corr).
import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(77)


def _operands(n, eps_a, eps_x):
    a = RNG.standard_normal((n, n)).astype(np.float32)
    x = RNG.standard_normal((n, 1)).astype(np.float32)
    # Paper Eq. 2/3: multiplicative row-wise / element-wise programming error.
    ea = (eps_a * RNG.standard_normal((n, 1))).astype(np.float32)
    ex = (eps_x * RNG.standard_normal((n, 1))).astype(np.float32)
    at = a * (1.0 + ea)  # row-wise error ε_{a_i}
    xt = x * (1.0 + ex)
    return a, at, x, xt


def _minv(n, lam=1e-12):
    return ref.denoise_inverse(n, lam).astype(np.float32)


def test_mvm_artifact_contract():
    n = 64
    a, at, x, xt = _operands(n, 0.05, 0.05)
    (y,) = model.mvm(jnp.asarray(at), jnp.asarray(xt))
    np.testing.assert_allclose(np.asarray(y), at @ xt, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("n", [32, 64, 128])
def test_ec_mvm_matches_oracle(n):
    a, at, x, xt = _operands(n, 0.05, 0.05)
    minv = _minv(n)
    nv, nu, ny = (1.0 + 0.003 * RNG.standard_normal((n, 1)).astype(np.float32)
                  for _ in range(3))
    got = model.ec_mvm(
        *[jnp.asarray(v) for v in (a, at, x, xt, minv, nv, nu, ny)]
    )
    want = ref.corrected_mvm_ref(a, at, x, xt, minv, nv, nu, ny)
    for g, w, name in zip(got, want, ("y_raw", "p", "y_corr")):
        np.testing.assert_allclose(
            np.asarray(g), w, rtol=5e-5, atol=5e-4, err_msg=name
        )


def test_first_order_cancellation_is_second_order():
    # ||p - Ax|| must scale like eps^2, not eps (the paper's Eq. 7 claim).
    n = 128
    b_errs = []
    for eps in (1e-2, 1e-3):
        a, at, x, xt = _operands(n, eps, eps)
        minv = _minv(n)
        ones = np.ones((n, 1), np.float32)
        _, p, _ = model.ec_mvm(
            *[jnp.asarray(v) for v in (a, at, x, xt, minv, ones, ones, ones)]
        )
        b = a @ x
        b_errs.append(np.linalg.norm(np.asarray(p) - b) / np.linalg.norm(b))
    # One decade in eps should shrink the residual ~two decades (allow slack
    # for f32 roundoff at the small end).
    assert b_errs[1] < b_errs[0] * 5e-2, b_errs


def test_raw_error_is_first_order():
    # Contrast: the uncorrected product degrades linearly in eps.
    n = 128
    eps = 1e-2
    a, at, x, xt = _operands(n, eps, eps)
    minv = _minv(n)
    ones = np.ones((n, 1), np.float32)
    y_raw, p, _ = model.ec_mvm(
        *[jnp.asarray(v) for v in (a, at, x, xt, minv, ones, ones, ones)]
    )
    b = a @ x
    raw = np.linalg.norm(np.asarray(y_raw) - b) / np.linalg.norm(b)
    cor = np.linalg.norm(np.asarray(p) - b) / np.linalg.norm(b)
    assert cor < raw * 0.1, (raw, cor)  # >90% reduction (headline claim)


def test_zero_noise_is_exact_passthrough():
    n = 64
    a = RNG.standard_normal((n, n)).astype(np.float32)
    x = RNG.standard_normal((n, 1)).astype(np.float32)
    minv = np.eye(n, dtype=np.float32)  # λ=0 limit
    ones = np.ones((n, 1), np.float32)
    y_raw, p, y_corr = model.ec_mvm(
        *[jnp.asarray(v) for v in (a, a, x, x, minv, ones, ones, ones)]
    )
    np.testing.assert_allclose(np.asarray(p), np.asarray(y_raw), rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_corr), np.asarray(p), rtol=2e-5, atol=2e-4)


def test_denoise_inverse_properties():
    # (I + λLᵀL) is SPD; its inverse times (I + λLᵀL) is I; λ→0 gives I.
    n = 66
    lam = 1e-12
    l = ref.difference_matrix(n)
    m = np.eye(n) + lam * l.T @ l
    minv = ref.denoise_inverse(n, lam)
    np.testing.assert_allclose(minv @ m, np.eye(n), atol=1e-10)
    np.testing.assert_allclose(minv, np.eye(n), atol=1e-10)


def test_denoise_attenuates_rough_noise():
    # With a non-trivial λ the denoiser must attenuate high-frequency noise
    # more than it distorts a smooth signal.
    n = 256
    lam = 0.5
    t = np.linspace(0, 1, n)
    smooth = np.sin(2 * np.pi * t)[:, None]
    noise = RNG.standard_normal((n, 1)) * 0.3
    minv = ref.denoise_inverse(n, lam).astype(np.float32)
    den = minv @ (smooth + noise).astype(np.float32)
    err_before = np.linalg.norm(smooth + noise - smooth)
    err_after = np.linalg.norm(den - smooth)
    assert err_after < err_before


def test_tile_sizes_exported():
    assert model.TILE_SIZES == (32, 64, 128, 256, 512, 1024)
    for n in model.TILE_SIZES:
        mat, vec = model.mvm_specs(n)
        assert mat.shape == (n, n) and vec.shape == (n, 1)
        assert len(model.ec_mvm_specs(n)) == 8
