# AOT pipeline tests: HLO text generation, manifest integrity, and a
# round-trip execution of generated HLO through the python XLA client
# (mirrors what the Rust PJRT runtime does).
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_lower_mvm_produces_hlo_text():
    text = aot.lower_artifact(model.mvm, model.mvm_specs(32))
    assert "ENTRY" in text and "HloModule" in text
    # f32[32,32] parameter present
    assert "f32[32,32]" in text


def test_lower_ec_mvm_has_three_outputs():
    text = aot.lower_artifact(model.ec_mvm, model.ec_mvm_specs(32))
    assert "ENTRY" in text
    # tuple root with three f32[32,1] elements
    assert "(f32[32,1]" in text


def test_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, sizes=[32])
    assert set(manifest["artifacts"]) == {"mvm_32", "ec_mvm_32"}
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(manifest))
    for meta in on_disk["artifacts"].values():
        path = os.path.join(out, meta["file"])
        assert os.path.getsize(path) == meta["bytes"]


def test_generated_hlo_numerics_via_stablehlo_roundtrip():
    # Execute the same lowered computation jax-side and compare to oracle —
    # proves the artifact's math; the text-reload path is proven in rust.
    n = 64
    rng = np.random.default_rng(5)
    a = rng.standard_normal((n, n)).astype(np.float32)
    x = rng.standard_normal((n, 1)).astype(np.float32)
    at = a * (1 + 0.03)
    xt = x * (1 - 0.02)
    minv = ref.denoise_inverse(n, 1e-12).astype(np.float32)
    ones = np.ones((n, 1), np.float32)
    compiled = jax.jit(model.ec_mvm).lower(*model.ec_mvm_specs(n)).compile()
    got = compiled(a, at, x, xt, minv, ones, ones, ones)
    want = ref.corrected_mvm_ref(a, at, x, xt, minv)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), w, rtol=5e-5, atol=5e-4)


def test_manifest_hashes_are_stable():
    t1 = aot.lower_artifact(model.mvm, model.mvm_specs(32))
    t2 = aot.lower_artifact(model.mvm, model.mvm_specs(32))
    assert aot._sha256(t1) == aot._sha256(t2)
