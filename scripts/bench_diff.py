#!/usr/bin/env python3
"""Diff fresh bench emissions against the committed repo-root baselines.

Usage:
    python3 scripts/bench_diff.py \
        --baseline-dir . --fresh-dir bench_results [--max-regression 0.20] \
        BENCH_plane_contention.json BENCH_sparse_dispatch.json ...

For every named file the script loads ``<baseline-dir>/<name>`` (the
committed baseline) and ``<fresh-dir>/<name>`` (what the bench just
emitted) and compares them:

* **ratio metrics** (higher is better): ``speedup``,
  ``speedup_chunks_per_s``, ``extract_stage_reduction``.  These are
  same-run throughput *ratios* (concurrent vs serialized admission,
  descriptor vs leader materialization), so they transfer across machines
  far better than absolute chunks/s.  A fresh value more than
  ``--max-regression`` (default 20%) below the baseline fails the diff.
* **exact metrics** (deterministic workload facts): ``chunks``,
  ``chunks_total``, ``chunks_planned``, ``max_shard_load``,
  ``deterministic``, ``bit_identical``.  Any change fails — these catch
  planning regressions (e.g. occupied-chunk enumeration dispatching more
  blocks) that wall clocks would hide.
* everything else (``wall_s``, ``chunks_per_s``, latencies) is
  informational only: absolute wall numbers do not transfer between
  machines, so they are printed but never gated.

A baseline whose ``provenance.status`` is ``"seed"`` (committed before
any measured run existed) gates nothing: the script prints a refresh
notice and exits 0.  To arm the gate, replace the repo-root baseline with
a measured emission — e.g. the ``bench-results`` artifact of a trusted CI
run — and set ``provenance.status`` to ``"measured"``.

Exit status: 0 when every gated metric holds, 1 otherwise.
"""

import argparse
import json
import os
import sys

RATIO_KEYS = {"speedup", "speedup_chunks_per_s", "extract_stage_reduction"}
EXACT_KEYS = {
    "chunks",
    "chunks_total",
    "chunks_planned",
    "max_shard_load",
    "deterministic",
    "bit_identical",
}


def walk(base, fresh, path, out):
    """Collect (path, key, baseline, fresh) for every leaf present in both."""
    if isinstance(base, dict) and isinstance(fresh, dict):
        for key in base:
            if key in fresh:
                walk(base[key], fresh[key], f"{path}.{key}" if path else key, out)
    elif isinstance(base, list) and isinstance(fresh, list):
        for i, (b, f) in enumerate(zip(base, fresh)):
            walk(b, f, f"{path}[{i}]", out)
    else:
        out.append((path, path.rsplit(".", 1)[-1].split("[")[0], base, fresh))


def diff_file(name, baseline_dir, fresh_dir, max_regression):
    """Return a list of failure strings for one bench emission."""
    base_path = os.path.join(baseline_dir, name)
    fresh_path = os.path.join(fresh_dir, name)
    if not os.path.exists(base_path):
        return [f"{name}: no committed baseline at {base_path}"]
    if not os.path.exists(fresh_path):
        return [f"{name}: bench did not emit {fresh_path}"]
    with open(base_path) as fh:
        base = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)

    status = base.get("provenance", {}).get("status", "measured")
    if status == "seed":
        print(
            f"  {name}: baseline is a SEED (no measured run committed yet) — "
            f"gating skipped.  Refresh: copy a trusted run's "
            f"bench_results/{name} over the repo-root baseline and set "
            f'provenance.status = "measured".'
        )
        return []

    leaves = []
    walk(base, fresh, "", leaves)
    failures = []
    gated = 0
    for path, key, b, f in leaves:
        if key in RATIO_KEYS and isinstance(b, (int, float)) and isinstance(f, (int, float)):
            gated += 1
            floor = b * (1.0 - max_regression)
            verdict = "ok" if f >= floor else "REGRESSION"
            print(f"  {name}:{path}: baseline {b:.3f} fresh {f:.3f} floor {floor:.3f} {verdict}")
            if f < floor:
                failures.append(
                    f"{name}:{path}: {f:.3f} fell more than "
                    f"{max_regression:.0%} below baseline {b:.3f}"
                )
        elif key in EXACT_KEYS:
            gated += 1
            if b != f:
                print(f"  {name}:{path}: baseline {b!r} fresh {f!r} CHANGED")
                failures.append(f"{name}:{path}: deterministic metric changed {b!r} -> {f!r}")
    if gated == 0:
        failures.append(f"{name}: measured baseline but no gated metrics found (schema drift?)")
    return failures


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("names", nargs="+", help="BENCH_*.json filenames to diff")
    ap.add_argument("--baseline-dir", default=".", help="directory of committed baselines")
    ap.add_argument("--fresh-dir", default="bench_results", help="directory of fresh emissions")
    ap.add_argument(
        "--max-regression",
        type=float,
        default=0.20,
        help="allowed fractional drop in ratio metrics (default 0.20)",
    )
    args = ap.parse_args()

    failures = []
    for name in args.names:
        print(f"diffing {name} (baseline {args.baseline_dir}, fresh {args.fresh_dir})")
        failures += diff_file(name, args.baseline_dir, args.fresh_dir, args.max_regression)
    if failures:
        print("\nFAIL:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nPASS: no gated bench metric regressed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
