#!/usr/bin/env python3
"""Promote fresh bench emissions to committed repo-root baselines.

Usage:
    python3 scripts/promote_baselines.py [bench_results] [--repo-root .]
    python3 scripts/promote_baselines.py bench_results BENCH_serve_coalescing.json

Copies every ``BENCH_*.json`` present in the fresh-emissions directory
(default ``bench_results``, the directory ``cargo bench`` writes and the
CI ``bench-results`` artifact unpacks to) over the matching repo-root
baseline.  A fresh emission carries no ``provenance`` block, which
``scripts/bench_diff.py`` treats as ``status = "measured"`` — so
promotion is exactly the "plain copy arms the gate" step the seed
baselines document in their ``provenance.refresh`` notes.

Guard rails, so a promotion is always a conscious upgrade:

* only baselines that already exist at the repo root are replaced — a
  stray emission never creates an ungated orphan baseline;
* an emission that *itself* carries ``provenance.status = "seed"`` is
  refused (promoting a placeholder over a placeholder is a no-op that
  would masquerade as a measurement);
* the script prints which gated metrics each promoted baseline now
  enforces, as a review aid for the commit that lands it.

Exit status: 0 if every requested baseline was promoted, 1 otherwise.
"""

import argparse
import json
import os
import shutil
import sys

GATED_KEYS = {
    "speedup",
    "speedup_chunks_per_s",
    "extract_stage_reduction",
    "chunks",
    "chunks_total",
    "chunks_planned",
    "max_shard_load",
    "deterministic",
    "bit_identical",
}


def gated_metrics(doc, path=""):
    """Every gated leaf in a bench emission, as dotted paths."""
    out = []
    if isinstance(doc, dict):
        for key, val in doc.items():
            sub = f"{path}.{key}" if path else key
            if key in GATED_KEYS and not isinstance(val, (dict, list)):
                out.append(f"{sub} = {val!r}")
            else:
                out.extend(gated_metrics(val, sub))
    elif isinstance(doc, list):
        for i, val in enumerate(doc):
            out.extend(gated_metrics(val, f"{path}[{i}]"))
    return out


def promote(name, fresh_dir, repo_root):
    """Copy one emission over its baseline.  Returns an error or None."""
    fresh_path = os.path.join(fresh_dir, name)
    base_path = os.path.join(repo_root, name)
    if not os.path.exists(fresh_path):
        return f"{name}: no fresh emission at {fresh_path} (run the bench first)"
    if not os.path.exists(base_path):
        return f"{name}: no committed baseline at {base_path} to replace"
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    if fresh.get("provenance", {}).get("status") == "seed":
        return f"{name}: refusing to promote — the emission is itself a seed placeholder"
    metrics = gated_metrics(fresh)
    if not metrics:
        return f"{name}: emission has no gated metrics (schema drift?)"
    shutil.copyfile(fresh_path, base_path)
    print(f"promoted {name}: the baseline-diff gate now enforces")
    for m in metrics:
        print(f"  {m}")
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "fresh_dir",
        nargs="?",
        default="bench_results",
        help="directory of fresh emissions (default: bench_results)",
    )
    ap.add_argument(
        "names",
        nargs="*",
        help="specific BENCH_*.json files (default: every baseline at the repo root)",
    )
    ap.add_argument("--repo-root", default=".", help="repository root (default: .)")
    args = ap.parse_args()

    names = args.names or sorted(
        f for f in os.listdir(args.repo_root) if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not names:
        print("no BENCH_*.json baselines found at the repo root")
        return 1

    failures = []
    for name in names:
        err = promote(name, args.fresh_dir, args.repo_root)
        if err:
            failures.append(err)
    if failures:
        print("\nNOT PROMOTED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nPASS: every baseline promoted to a measured emission")
    return 0


if __name__ == "__main__":
    sys.exit(main())
