//! End-to-end driver (DESIGN.md E8): exercises the FULL stack — AOT PJRT
//! artifacts, device/MCA simulation, write–verify, two-tier EC,
//! virtualization and the distributed coordinator — on the paper's
//! headline workload, and checks the three headline claims:
//!
//!   1. EC reduces first/second-order arithmetic error by >90%;
//!   2. with EC, the low-precision TaOx-HfOx matches/beats the EpiRAM
//!      reference's no-EC accuracy;
//!   3. while keeping ≥3 orders of magnitude less write energy and ≥1.5
//!      orders less write latency.
//!
//! The run is recorded in EXPERIMENTS.md.  Exit code 0 = all claims hold.
//!
//! ```sh
//! cargo run --release --example end_to_end [-- --reps N]
//! ```

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::metrics::table::TableBuilder;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;
use meliso::util::sci;

fn main() {
    let args = BenchArgs::parse();
    let reps = args.reps_or(3, 8, 100);
    let backend = backend();
    let system = SystemConfig::single_mca(128);

    println!("=== MELISO+ end-to-end driver ({reps} reps per cell) ===\n");
    let mut failures = Vec::new();

    for (label, matrix) in [("M1 bcsstk02", "bcsstk02"), ("M2 iperturb", "iperturb66")] {
        let source = registry::build(matrix).unwrap();
        let x = Vector::standard_normal(source.ncols(), 0x5eed);

        let mut table = TableBuilder::new(
            &format!("{label} ({}²)", source.nrows()),
            &["eps_l2 raw", "eps_l2 EC", "reduction", "E_w EC (J)", "L_w EC (s)"],
        );

        let mut epiram_raw = (0.0, 0.0, 0.0); // (err, ew, lw)
        let mut taox_ec = (0.0, 0.0, 0.0);

        for material in Material::ALL {
            let run = |ec: bool, k: usize| {
                let opts = SolveOptions::default()
                    .with_device(material)
                    .with_ec(ec)
                    .with_wv_iters(k);
                let solver = Meliso::with_backend(system, opts, backend.clone());
                let reports = solver.replicate(source.as_ref(), &x, reps).unwrap();
                ReplicationSummary::from_reports(&reports)
            };
            let raw = run(false, 0);
            let ec = run(true, 5);
            let reduction = 1.0 - ec.rel_err_l2 / raw.rel_err_l2.max(1e-30);
            table.row(
                material.name(),
                vec![
                    sci(raw.rel_err_l2),
                    sci(ec.rel_err_l2),
                    format!("{:.1}%", reduction * 100.0),
                    sci(ec.ew_mean),
                    sci(ec.lw_mean),
                ],
            );
            if material == Material::EpiRam {
                epiram_raw = (raw.rel_err_l2, raw.ew_mean, raw.lw_mean);
            }
            if material == Material::TaOxHfOx {
                taox_ec = (ec.rel_err_l2, ec.ew_mean, ec.lw_mean);
            }
            // Claim 1: >90% error reduction for the noisy devices on the
            // ill-conditioned workload.
            if matrix == "bcsstk02" && material != Material::EpiRam && reduction < 0.9 {
                failures.push(format!(
                    "claim 1 FAILED: {material} on {matrix}: reduction {:.1}% < 90%",
                    reduction * 100.0
                ));
            }
        }
        print!("{}", table.render());

        if matrix == "bcsstk02" {
            // Claim 2: TaOx+EC accuracy <= EpiRAM raw accuracy.
            if taox_ec.0 > epiram_raw.0 {
                failures.push(format!(
                    "claim 2 FAILED: TaOx+EC eps {:.4} > EpiRAM eps {:.4}",
                    taox_ec.0, epiram_raw.0
                ));
            }
            // Claim 3: energy/latency advantages survive EC.
            let e_orders = (epiram_raw.1 / taox_ec.1).log10();
            let l_orders = (epiram_raw.2 / taox_ec.2).log10();
            println!(
                "TaOx-HfOx+EC vs EpiRAM: {:.1} orders less energy, {:.1} orders less latency\n",
                e_orders, l_orders
            );
            if e_orders < 3.0 {
                failures.push(format!("claim 3 FAILED: energy advantage {e_orders:.2} < 3 orders"));
            }
            if l_orders < 1.5 {
                failures.push(format!("claim 3 FAILED: latency advantage {l_orders:.2} < 1.5 orders"));
            }
        }
    }

    // Distributed leg: run the weak-scaling workload once to prove the
    // virtualization + coordinator path composes with EC and PJRT.
    println!("--- distributed leg: add32 (4960²) on 8x8 tiles of 512² cells ---");
    let source = registry::build("add32").unwrap();
    let x = Vector::standard_normal(source.ncols(), 0x5eed);
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_ec(true)
        .with_wv_iters(2)
        .with_workers(4);
    let solver = Meliso::with_backend(SystemConfig::tiles_8x8(512), opts, backend.clone());
    let report = solver.solve_source(source.as_ref(), &x).unwrap();
    println!(
        "eps_l2 {:.4e}, {} chunks ({} skipped by sparsity), {} MCAs, wall {:.2}s",
        report.rel_err_l2,
        report.chunks_total,
        report.chunks_skipped,
        report.mcas_used,
        report.wall_seconds
    );
    if report.rel_err_l2 > 0.1 {
        failures.push(format!(
            "distributed leg accuracy regression: eps {:.4}",
            report.rel_err_l2
        ));
    }

    println!();
    if failures.is_empty() {
        println!("ALL HEADLINE CLAIMS REPRODUCED ✓");
    } else {
        for f in &failures {
            eprintln!("{f}");
        }
        std::process::exit(1);
    }
}
