//! Quickstart: solve one in-memory MVM with error correction and print the
//! report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use meliso::prelude::*;

fn main() -> Result<(), String> {
    // 1. Pick an operand (a 66x66 near-identity matrix, the paper's M2)
    //    and a standard-normal input vector.
    let a = meliso::matrices::registry::build("iperturb66")?;
    let x = Vector::standard_normal(a.ncols(), 7);

    // 2. Configure a single 128² crossbar of TaOx-HfOx devices — the low-
    //    energy, low-precision material the paper champions — with the
    //    two-tier error correction and 2 write-verify iterations.
    let system = SystemConfig::single_mca(128);
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_ec(true)
        .with_wv_iters(2);

    // 3. Build the solver.  `Meliso::new` starts the PJRT runtime and loads
    //    the AOT artifacts from ./artifacts (falls back with a clear error
    //    if `make artifacts` has not run).
    let solver = match Meliso::new(system, opts.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("note: {e}\nfalling back to the native backend");
            Meliso::with_backend(
                system,
                opts.with_backend(BackendKind::Native),
                std::sync::Arc::new(meliso::runtime::native::NativeBackend::new()),
            )
        }
    };

    // 4. Solve and inspect.
    let report = solver.solve_source(a.as_ref(), &x)?;
    println!("backend          : {}", solver.backend_name());
    println!("rel l2 error     : {:.4e}", report.rel_err_l2);
    println!("rel linf error   : {:.4e}", report.rel_err_inf);
    println!("write energy (J) : {:.4e}", report.ew_mean);
    println!("write latency (s): {:.4e}", report.lw_mean);
    println!("wall time (s)    : {:.3}", report.wall_seconds);

    // The corrected in-memory result is in report.y; compare a few entries
    // against the exact product.
    let b = a.matvec(&x);
    for i in 0..4 {
        println!(
            "y[{i}] = {:+.5}   (exact {:+.5})",
            report.y.get(i),
            b.get(i)
        );
    }
    Ok(())
}
