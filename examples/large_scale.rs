//! Large-scale virtualization demo: run a matrix that exceeds the physical
//! multi-MCA capacity and watch the virtualization layer partition,
//! zero-pad, schedule and aggregate — the paper's §2.3 capability
//! (dimensions up to 65,025² with `--size dubcova2`).
//!
//! ```sh
//! cargo run --release --example large_scale -- [--size dubcova1] [--cell 1024]
//! ```

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::prelude::*;
use meliso::virtualization::ChunkPlan;

fn main() -> Result<(), String> {
    let args = BenchArgs::parse();
    let mut name = "dubcova1".to_string();
    let mut cell = 1024usize;
    let mut it = args.rest.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--size" => name = it.next().cloned().ok_or("--size needs a value")?,
            "--cell" => {
                cell = it
                    .next()
                    .ok_or("--cell needs a value")?
                    .parse()
                    .map_err(|e| format!("--cell: {e}"))?
            }
            other => return Err(format!("unknown arg {other:?}")),
        }
    }

    let source = registry::build(&name)?;
    let n = source.nrows();
    let system = SystemConfig::tiles_8x8(cell);
    let plan = ChunkPlan::new(system.geometry(), n, n);
    let (cap_r, cap_c) = system.geometry().capacity();

    println!("operand        : {name} ({n} x {n})");
    println!("physical system: 8x8 MCAs of {cell}² cells -> capacity {cap_r} x {cap_c}");
    println!(
        "virtualization : {} x {} chunk grid, {} chunks, normalization factor {}",
        plan.grid_rows,
        plan.grid_cols,
        plan.total_chunks(),
        plan.row_reassignments()
    );
    if plan.fits_physically() {
        println!("                 (fits physically — single-pass execution)");
    } else {
        println!(
            "                 (exceeds capacity — each MCA reassigned up to {} times)",
            plan.normalization_factor()
        );
    }

    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_ec(true)
        .with_wv_iters(1)
        .with_workers(4);
    let solver = Meliso::with_backend(system, opts, backend());
    println!("\nsolving …");
    let report = solver.solve_source(source.as_ref(), &x_for(source.ncols()))?;
    println!("rel l2 error        : {:.4e}", report.rel_err_l2);
    println!("rel linf error      : {:.4e}", report.rel_err_inf);
    println!("chunks executed     : {}", report.chunks_total - report.chunks_skipped);
    println!("chunks skipped      : {} (sparsity-aware)", report.chunks_skipped);
    println!("MCAs used           : {}", report.mcas_used);
    println!("E_w mean/MCA (J)    : {:.4e}", report.ew_mean);
    println!("L_w mean/MCA (s)    : {:.4e}", report.lw_mean);
    println!(
        "L_w normalized (s)  : {:.4e}  (÷{} reassignments)",
        report.lw_mean / report.row_reassignments as f64,
        report.row_reassignments
    );
    println!("wall time (s)       : {:.2}", report.wall_seconds);
    Ok(())
}

fn x_for(n: usize) -> Vector {
    Vector::standard_normal(n, 0x5eed)
}
