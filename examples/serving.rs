//! Serving quickstart: keep an operand resident on the crossbar grid and
//! serve many solves against it (program once / solve many), then share
//! the grid between tenants through the LRU operand cache.
//!
//! ```sh
//! cargo run --release --example serving
//! ```

use meliso::prelude::*;
use meliso::server::OperandCache;

fn main() -> Result<(), String> {
    // 1. A solver configured like the quickstart example; fall back to the
    //    native backend when the PJRT artifacts are absent.
    let system = SystemConfig::single_mca(128);
    let opts = SolveOptions::default()
        .with_device(Material::TaOxHfOx)
        .with_wv_iters(2);
    let solver = match Meliso::new(system, opts.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("note: {e}\nfalling back to the native backend");
            Meliso::with_backend(
                system,
                opts.with_backend(BackendKind::Native),
                std::sync::Arc::new(meliso::runtime::native::NativeBackend::new()),
            )
        }
    };

    // 2. Program the operand once.  This is the expensive step: the full
    //    adjustableWriteandVerify pass over every non-zero chunk.
    let a = meliso::matrices::registry::build("iperturb66")?;
    let session = solver.open_session(a.clone())?;
    let p = session.program_report();
    println!(
        "programmed {}x{} ({} resident chunks) in {:.3}s for {:.3e} J",
        p.m, p.n, p.chunks_resident, p.wall_seconds, p.write_energy_j
    );

    // 3. Serve: each solve pays only the input-vector encode and the
    //    crossbar reads.  Batches amortize dispatch over one chunk walk.
    let xs: Vec<Vector> = (0..32)
        .map(|i| Vector::standard_normal(a.ncols(), 100 + i))
        .collect();
    for chunk in xs.chunks(8) {
        session.solve_batch(chunk)?;
    }
    let one = session.solve(&xs[0])?;
    let b = a.matvec(&xs[0]);
    let rel = one.y.sub(&b).norm_l2() / b.norm_l2();
    println!("solve #{}: rel l2 error {:.3e}", one.solve_index, rel);
    println!("{}", session.report().render());

    // 4. Multi-operand residency on ONE plane: program several operands
    //    onto the same shard pool and serve them interleaved.  Results are
    //    bit-identical to dedicated planes; eviction (session drop) frees
    //    the tile slots for the next tenant.
    let a2 = meliso::matrices::registry::build("bcsstk02")?;
    let plane = solver.build_plane(a.as_ref())?;
    let sa = solver.open_session_on(&plane, a.clone())?;
    let sb = solver.open_session_on(&plane, a2.clone())?;
    // Sessions admit batches through `&self`, so different tenants solve
    // concurrently on the one shard pool.
    std::thread::scope(|s| {
        let ha = s.spawn(|| sa.solve(&Vector::standard_normal(a.ncols(), 200)));
        let hb = s.spawn(|| sb.solve(&Vector::standard_normal(a2.ncols(), 201)));
        ha.join().expect("tenant A thread")?;
        hb.join().expect("tenant B thread")?;
        Ok::<(), PlaneError>(())
    })?;
    println!(
        "shared plane: {} operands resident, {} tile slots in use on {} shards",
        plane.resident_operands(),
        plane.slots_in_use(),
        plane.shards()
    );
    drop(sb); // evicts bcsstk02's residency, slots return to the allocator

    // 5. Multi-tenant residency behind an LRU cache keyed by operand
    //    content (all entries share one plane).  The second lookup of
    //    bcsstk02 skips programming entirely.
    let mut cache = OperandCache::new(2);
    let tenant = meliso::matrices::registry::build("bcsstk02")?;
    let s1 = cache.get_or_open(&solver, &tenant)?;
    let s2 = cache.get_or_open(&solver, &tenant)?;
    let x = Vector::standard_normal(tenant.ncols(), 7);
    s2.solve(&x)?;
    println!(
        "cache: {} hits / {} misses, tenants resident: {}, shared: {}",
        cache.hits,
        cache.misses,
        cache.len(),
        std::sync::Arc::ptr_eq(&s1, &s2)
    );
    Ok(())
}
