//! Device-technology exploration: sweep all four RRAM materials across
//! write-verify budgets and EC settings on a workload of your choice,
//! printing a decision matrix — which device to pick at a given accuracy
//! target, and what it costs in energy and latency.
//!
//! ```sh
//! cargo run --release --example device_comparison -- [matrix] [--reps N]
//! ```

use meliso::bench::{backend, BenchArgs};
use meliso::device::materials::Material;
use meliso::matrices::registry;
use meliso::metrics::table::TableBuilder;
use meliso::prelude::*;
use meliso::solver::ReplicationSummary;
use meliso::util::sci;

fn main() -> Result<(), String> {
    let args = BenchArgs::parse();
    let matrix = args
        .rest
        .first()
        .cloned()
        .unwrap_or_else(|| "bcsstk02".to_string());
    let reps = args.reps_or(2, 5, 20);
    let backend = backend();

    let source = registry::build(&matrix)?;
    let n = source.nrows();
    if n > 2048 {
        return Err("pick a small operand (<=2048) for this example".into());
    }
    let x = Vector::standard_normal(source.ncols(), 11);
    let cell = meliso::runtime::fit_tile(&backend.tile_sizes(), n);
    let system = SystemConfig::single_mca(cell);

    println!("# device comparison on {matrix} ({n}²), cell {cell}², {reps} reps\n");
    let mut table = TableBuilder::new(
        "accuracy / energy / latency decision matrix",
        &["mode", "eps_l2", "E_w (J)", "L_w (s)", "E·L product"],
    );
    let mut best: Option<(String, f64)> = None;
    for material in Material::ALL {
        for (mode, ec, k) in [
            ("raw      ", false, 0),
            ("wv k=5   ", false, 5),
            ("EC       ", true, 0),
            ("EC+wv k=5", true, 5),
        ] {
            let opts = SolveOptions::default()
                .with_device(material)
                .with_ec(ec)
                .with_wv_iters(k);
            let solver = Meliso::with_backend(system, opts, backend.clone());
            let reports = solver.replicate(source.as_ref(), &x, reps)?;
            let s = ReplicationSummary::from_reports(&reports);
            table.row(
                &format!("{:<10}", material.name()),
                vec![
                    mode.to_string(),
                    sci(s.rel_err_l2),
                    sci(s.ew_mean),
                    sci(s.lw_mean),
                    sci(s.ew_mean * s.lw_mean),
                ],
            );
            // "Best" = accurate enough (<5% error) with the smallest E·L.
            if s.rel_err_l2 < 0.05 {
                let cost = s.ew_mean * s.lw_mean;
                if best.as_ref().map(|(_, c)| cost < *c).unwrap_or(true) {
                    best = Some((format!("{} {}", material.name(), mode.trim()), cost));
                }
            }
        }
    }
    print!("{}", table.render());
    match best {
        Some((choice, _)) => println!("\nbest <5%-error configuration by E*L: {choice}"),
        None => println!("\nno configuration reached <5% error — increase k or enable EC"),
    }
    Ok(())
}
