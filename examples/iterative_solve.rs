//! Iterative Ax = b quickstart: solve a linear system where every Krylov
//! iteration is an in-memory MVM against a resident crossbar session —
//! the operand is write–verified once, every iteration afterwards is
//! read-only, and exact f64 host-side refinement drives the residual far
//! below the device's per-MVM error floor.
//!
//! ```sh
//! cargo run --release --example iterative_solve
//! ```

use meliso::prelude::*;

fn main() -> Result<(), String> {
    // 1. A solver on one 64² MCA; fall back to the native backend when
    //    the PJRT artifacts are absent.
    let system = SystemConfig::single_mca(64);
    let opts = SolveOptions::default()
        .with_device(Material::EpiRam)
        .with_wv_iters(3)
        .with_seed(42);
    let solver = match Meliso::new(system, opts.clone()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("note: {e}\nfalling back to the native backend");
            Meliso::with_backend(
                system,
                opts.with_backend(BackendKind::Native),
                std::sync::Arc::new(meliso::runtime::native::NativeBackend::new()),
            )
        }
    };

    // 2. CG on a well-conditioned SPD registry operand.  The right-hand
    //    side comes from a known solution so the true error is visible.
    let a = meliso::matrices::registry::build("spd64")?;
    let x_star = Vector::standard_normal(a.ncols(), 7);
    let b = a.matvec(&x_star);
    let cg = IterOptions::default()
        .with_method(Method::Cg)
        .with_tol(1e-6)
        .with_max_iters(40)
        .with_refinements(50);
    let report = solver.solve_system(a, &b, &cg)?;
    println!("{}", report.render());
    let err = report.x.sub(&x_star).norm_l2() / x_star.norm_l2();
    println!("true solution error: {err:.3e}");
    println!(
        "residual trajectory (outer): {:?}",
        report
            .residual_history
            .iter()
            .map(|r| format!("{r:.1e}"))
            .collect::<Vec<_>>()
    );

    // 3. GMRES(m) handles the nonsymmetric operands the same way.
    let a = meliso::matrices::registry::build("nonsym64")?;
    let b = a.matvec(&Vector::standard_normal(a.ncols(), 9));
    let gmres = IterOptions::default()
        .with_method(Method::Gmres)
        .with_restart(24)
        .with_tol(1e-5)
        .with_max_iters(48)
        .with_refinements(50);
    let report = solver.solve_system(a, &b, &gmres)?;
    println!("\n{}", report.render());
    Ok(())
}
